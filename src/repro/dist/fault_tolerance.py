"""Fault-tolerance policies for the train loop.

Gao et al.'s almost-wait-free table keeps serving while individual
processes stall or die; the training-system analogue implemented here:

- ``StepWatchdog``     — a stalled step (deadlocked collective, hung host)
  raises instead of hanging the job forever; the runner restarts from the
  last checkpoint.
- ``StragglerMonitor`` — detects chips running persistently slower than the
  fleet median and escalates ok -> straggler -> replan.
- ``elastic_plan``     — after losing hosts, pick the best mesh the
  remaining chips support; ``accum_for`` keeps the effective global batch
  via gradient accumulation.  Restore onto the new mesh goes through
  ``training/checkpoint.restore(..., rules=...)``.

Host-side Python (no jax) — policies run between steps, never inside jit.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Optional, Tuple

POD_CHIPS = 256     # one pod = 16x16 chips


class WatchdogTimeout(RuntimeError):
    """A training step exceeded its deadline."""


class StepWatchdog:
    """Arm before launching a step; ``check`` after the sync point raises
    ``WatchdogTimeout`` when the step overran ``deadline_s``."""

    def __init__(self, deadline_s: float):
        self.deadline_s = float(deadline_s)
        self._armed_at: Optional[float] = None
        self._step: Optional[int] = None

    def arm(self, step: int) -> None:
        self._step = int(step)
        self._armed_at = time.monotonic()

    def check(self) -> float:
        """Elapsed seconds since ``arm``; raises on overrun, 0.0 if idle."""
        if self._armed_at is None:
            return 0.0
        elapsed = time.monotonic() - self._armed_at
        if elapsed > self.deadline_s:
            raise WatchdogTimeout(
                f"step {self._step} exceeded deadline "
                f"({elapsed:.1f}s > {self.deadline_s:.1f}s)")
        return elapsed

    def disarm(self) -> None:
        self._armed_at = None


class StragglerMonitor:
    """Per-step duration monitor.  ``observe(step, dt)`` returns:

    - ``"ok"``        — dt within ``threshold`` x the rolling median
    - ``"straggler"`` — slow step (not yet ``patience`` in a row)
    - ``"replan"``    — ``patience`` consecutive slow steps: re-shard /
      swap in a hot spare

    Slow steps are excluded from the baseline so a stalling chip cannot
    drag the median up under itself."""

    def __init__(self, threshold: float = 2.0, patience: int = 3,
                 window: int = 64, min_samples: int = 3):
        self.threshold = float(threshold)
        self.patience = int(patience)
        self.min_samples = int(min_samples)
        self._history: Deque[float] = deque(maxlen=window)
        self._consecutive = 0

    def baseline(self) -> Optional[float]:
        if len(self._history) < self.min_samples:
            return None
        ordered = sorted(self._history)
        return ordered[len(ordered) // 2]

    def observe(self, step: int, dt: float) -> str:
        base = self.baseline()
        if base is not None and dt > self.threshold * base:
            self._consecutive += 1
            if self._consecutive >= self.patience:
                self._consecutive = 0
                return "replan"
            return "straggler"
        self._consecutive = 0
        self._history.append(float(dt))
        return "ok"


def elastic_plan(n_chips: int, model_parallel: int) -> Tuple[Tuple[int, ...],
                                                             Tuple[str, ...]]:
    """Best mesh for ``n_chips`` at a fixed TP width.

    Multiple full pods -> (pod, data, model); anything else (e.g. a partial
    pod after losing a host) collapses the pod axis into data so every
    surviving chip keeps working: (data, model)."""
    if model_parallel <= 0 or n_chips % model_parallel:
        raise ValueError(f"{n_chips} chips not divisible by "
                         f"model_parallel={model_parallel}")
    if n_chips % POD_CHIPS == 0 and n_chips > POD_CHIPS \
            and POD_CHIPS % model_parallel == 0:
        pods = n_chips // POD_CHIPS
        return ((pods, POD_CHIPS // model_parallel, model_parallel),
                ("pod", "data", "model"))
    return ((n_chips // model_parallel, model_parallel), ("data", "model"))


def elastic_table_plan(manifest, lost_shard: int, *,
                       chips_per_group: int = POD_CHIPS,
                       model_parallel: int = 16):
    """The serving-side elastic recovery in one step: losing a host group
    (a) picks the best surviving mesh (``elastic_plan`` — the pod axis
    collapses when only one full pod survives) and (b) reassigns the dead
    shard's hash-prefix ranges to the survivors
    (``table_shard.ShardManifest.reassign`` — survivors keep their own
    ranges, so live sequences elsewhere are undisturbed).  Returns
    ``(new_manifest, mesh_shape, axis_names)``; re-admitting the lost
    lanes is the scheduler router's job (``sched/router.lose_host`` runs
    the recompute-preemption path).

    The two halves must agree: the mesh's surviving host-group count and
    ``new_manifest.live_shards()`` describe the same fleet, which is what
    ``tests/test_dist.py`` pins."""
    new_manifest = manifest.reassign(lost_shard)
    survivors = len(new_manifest.live_shards())
    shape, names = elastic_plan(survivors * chips_per_group, model_parallel)
    return new_manifest, shape, names


def accum_for(target_batch: int, actual: int) -> int:
    """Gradient-accumulation steps keeping effective batch >= target after
    an elastic resize shrank the per-step batch to ``actual``."""
    if actual <= 0:
        raise ValueError("actual batch must be positive")
    return max(1, -(-target_batch // actual))
