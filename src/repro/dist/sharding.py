"""Logical-axis -> mesh-axis sharding rules.

Every parameter / state pytree in the repo carries a parallel tree of
*logical axis names* (see ``models/nn.py``).  A ``ShardingRules`` instance
maps those names onto the axes of a concrete ``jax.sharding.Mesh``,
divisibility-aware: a mapping only applies when the dim size is divisible by
the mapped mesh-axis product, so the SAME rule tables drive the 512-chip
production mesh and a 2x2 CPU test mesh (non-dividing dims just stay
replicated).

Three presets:

- ``train_rules``  — batch over (pod, data); Megatron TP over ``model``
  (heads / kv / mlp / experts / vocab); FSDP-style weight sharding of the
  ``embed`` dim over ``data``.
- ``serve_rules``  — decode activations replicated (KB-scale), weights TP
  over ``model``, page pools sharded over every mesh axis, per-sequence
  state (ring buffers, SSM state) over ``data``.
- ``serve_manual_rules`` — the fused manual-TP decode layout (``tp_impl=
  "manual"``, see ``serving/engine.py``): page pools sharded over the page
  dim on (pod, data) only and over KV *heads* on ``model``, so the one
  fully-manual decode region keeps heads resident per chip and never
  gathers K/V across the model axis.
- ``dp_rules``     — pure data parallel: batch over (pod, data); experts
  unmapped (MoE falls back to its no-dispatch DP path); weights FSDP over
  ``model`` since TP is unused.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A rule value is a preference-ordered tuple of mesh axis names; axes absent
# from the mesh are skipped, and the longest present prefix whose size
# product divides the dim is used.
Rules = Dict[str, Tuple[str, ...]]


def _as_tuple(v) -> Tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: jax.sharding.Mesh
    rules: Dict[str, Tuple[str, ...]]
    mode: str = "train"              # "train" | "serve"

    # -- core resolution --------------------------------------------------

    def axis_for(self, name: Optional[str], size: int,
                 exclude: frozenset = frozenset()):
        """Mesh axes (str for one, tuple for several, None for unmapped)
        that logical axis ``name`` shards over for a dim of ``size``."""
        if name is None:
            return None
        want = tuple(a for a in self.rules.get(name, ())
                     if a in self.mesh.shape and a not in exclude)
        picked = []
        prod = 1
        for a in want:
            n = self.mesh.shape[a]
            if size % (prod * n) != 0:
                break
            picked.append(a)
            prod *= n
        if not picked or prod == 1:
            return None
        return picked[0] if len(picked) == 1 else tuple(picked)

    def spec(self, logical: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             exclude: frozenset = frozenset()) -> P:
        """PartitionSpec for a value of ``shape`` annotated with ``logical``
        axis names.  Each mesh axis is used at most once (first dim wins)."""
        logical = tuple(logical) + (None,) * (len(shape) - len(logical))
        used: set = set(exclude)
        entries = []
        for name, size in zip(logical, shape):
            got = self.axis_for(name, size, exclude=frozenset(used))
            if got is not None:
                used.update((got,) if isinstance(got, str) else got)
            entries.append(got)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    # -- pytree helpers ---------------------------------------------------

    def tree_shardings(self, axes_tree, sds_tree):
        """NamedSharding pytree for ``sds_tree`` (ShapeDtypeStructs/arrays)
        given the parallel logical-axes pytree ``axes_tree``."""
        return jax.tree.map(
            lambda ax, s: NamedSharding(self.mesh,
                                        self.spec(_as_tuple(ax), s.shape)),
            axes_tree, sds_tree, is_leaf=_is_axes_leaf)

    def tree_specs(self, axes_tree, sds_tree):
        return jax.tree.map(
            lambda ax, s: self.spec(_as_tuple(ax), s.shape),
            axes_tree, sds_tree, is_leaf=_is_axes_leaf)

    # -- derived rule sets ------------------------------------------------

    def drop(self, *mesh_axes: str) -> "ShardingRules":
        """A copy that never shards over ``mesh_axes`` (e.g. inside a
        shard_map region where those axes are manual)."""
        gone = set(mesh_axes)
        return ShardingRules(
            mesh=self.mesh,
            rules={k: tuple(a for a in v if a not in gone)
                   for k, v in self.rules.items()},
            mode=self.mode)


def _is_axes_leaf(x) -> bool:
    """Logical-axes leaves are plain tuples of names/None (incl. ``()`` for
    scalars) or bare None.  NamedTuples (pytree nodes) are excluded."""
    return x is None or (type(x) is tuple
                         and all(e is None or isinstance(e, str) for e in x))


# ---------------------------------------------------------------------------
# Rule tables.

_TP_WEIGHTS = {
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "mlp_shard": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
}


def train_rules(mesh) -> ShardingRules:
    """Training: DP over (pod, data), Megatron TP over model, FSDP of the
    embed dim over data."""
    rules: Rules = {
        "batch": ("pod", "data"),
        "embed": ("data",),          # FSDP / ZeRO-3 style weight sharding
        "pages": ("pod", "data", "model"),
        **_TP_WEIGHTS,
    }
    return ShardingRules(mesh=mesh, rules=rules, mode="train")


def serve_rules(mesh) -> ShardingRules:
    """Decode: activations replicated, weights TP over model, page pools
    over every axis, per-sequence state over data."""
    rules: Rules = {
        "batch": ("data",),
        "pages": ("pod", "data", "model"),
        **_TP_WEIGHTS,
    }
    return ShardingRules(mesh=mesh, rules=rules, mode="serve")


def serve_manual_rules(mesh) -> ShardingRules:
    """Fused manual-TP decode: pages over (pod, data) ONLY — the model axis
    shards KV *heads* instead (``"kv"`` rule), matching the in_specs of the
    single manual shard_map region in ``serving/engine.py``.  Weights stay
    Megatron-TP over model; activations replicated.  When the model axis is
    wider than ``n_kv``, the engine TILES the pool/ring head dim to
    ``n_kv·rep`` (``dist/tp.decode_kv_rep``) so the same ``"kv"`` mapping
    divides — the replicated-KV-head layout needs no extra rule here."""
    rules: Rules = {
        "batch": ("data",),
        "pages": ("pod", "data"),
        **_TP_WEIGHTS,
    }
    return ShardingRules(mesh=mesh, rules=rules, mode="serve")


def dp_rules(mesh) -> ShardingRules:
    """Pure data parallel (dry-run ``rules=dp`` preset): no TP anywhere;
    the model axis is reused for FSDP weight sharding."""
    rules: Rules = {
        "batch": ("pod", "data"),
        "embed": ("model",),
        "pages": ("pod", "data", "model"),
    }
    return ShardingRules(mesh=mesh, rules=rules, mode="train")
