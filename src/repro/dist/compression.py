"""Int8 gradient compression with error feedback.

Cross-pod (DCN) gradient reduction is bandwidth-bound; int8 cuts wire bytes
4x vs f32.  Plain quantization biases the update; error feedback carries the
per-pod quantization residual into the next step, so nothing is lost in
expectation (see ``tests/test_training.py::
test_compression_error_feedback_reduces_bias``).

Scales are per-tensor symmetric (absmax / 127) — round-to-nearest error is
bounded by half a quantization step, well inside the
``test_compression_quantize_roundtrip`` bound of one step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0


def _quantize(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape, float) -> (q int8 flat [n], scale f32 scalar)."""
    flat = x.astype(jnp.float32).reshape(-1)
    absmax = jnp.max(jnp.abs(flat))
    scale = jnp.maximum(absmax, 1e-30) / _QMAX
    q = jnp.clip(jnp.round(flat / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, n: int) -> jnp.ndarray:
    """Inverse of ``_quantize``: first ``n`` elements as f32."""
    return q[:n].astype(jnp.float32) * scale


def compress_leaf(g, err) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One error-feedback round for a gradient leaf: returns (sent, err')
    where ``sent`` is what goes on the wire (dequantized back to g's shape)
    and ``err'`` the residual to carry."""
    x32 = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize(x32)
    sent = _dequantize(q, scale, x32.size).reshape(g.shape)
    return sent, x32 - sent


def tree_compressed_psum(grads, axis_name: str, err):
    """Compressed all-reduce over a (manual) mesh axis with error feedback.

    Each participant quantizes (grad + residual) to int8, the dequantized
    contributions are summed over ``axis_name``, and the local residual is
    returned for the next step.  Returns (summed_grads, err') — the caller
    divides by the axis size if it wants a mean."""
    pairs = jax.tree.map(compress_leaf, grads, err)
    sent, err2 = jax.tree.transpose(jax.tree.structure(grads),
                                    jax.tree.structure((0, 0)), pairs)
    summed = jax.tree.map(lambda s: jax.lax.psum(s, axis_name), sent)
    summed = jax.tree.map(lambda s, g: s.astype(g.dtype), summed, grads)
    return summed, err2


def compressed_bytes(tree) -> int:
    """Wire bytes for one compressed reduction of ``tree`` (int8 payload +
    one f32 scale per leaf)."""
    return sum(int(x.size) + 4 for x in jax.tree.leaves(tree))
