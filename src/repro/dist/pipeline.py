"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

Layers are range-partitioned over the pipeline axis (stage s owns layers
[s*L/S, (s+1)*L/S)); the batch is split into M microbatches that flow
through the stages with ``ppermute`` shifts.  Classic GPipe fill/drain:
M + S - 1 ticks, bubble fraction (S-1)/(M+S-1).

The shard_map region is fully manual over EVERY mesh axis (the pinned XLA
rejects partially-auto regions around loop-heavy layer bodies — see
``dist/compat.py``); non-pipeline axes see replicated inputs and redundantly
compute the same stage, which is numerically identical.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map


def bubble_fraction(microbatches: int, stages: int) -> float:
    """Idle fraction of the GPipe schedule."""
    return (stages - 1) / (microbatches + stages - 1)


def _pipeline_axis(mesh) -> str:
    if "pod" in mesh.shape:
        return "pod"
    return mesh.axis_names[0]


def make_pipelined_forward(cfg, mesh, apply_range: Callable,
                           microbatches: int = 4) -> Callable:
    """Returns ``fwd(w_stack, x)`` == ``apply_range(w_stack, x)`` computed
    as an S-stage pipeline.

    ``apply_range(w_local, x)`` must apply a [L_local, ...] stack of layer
    weights sequentially to ``x`` — the same callable runs the whole model
    on one chip (S=1) and one stage of it here.  ``x`` is [B, ...] with
    B % microbatches == 0; ``cfg.num_layers % stages == 0``."""
    axis = _pipeline_axis(mesh)
    S = mesh.shape[axis]
    M = int(microbatches)
    L = cfg.num_layers
    if L % S:
        raise ValueError(f"num_layers={L} not divisible by {S} stages")

    def fwd(w_stack, x):
        B = x.shape[0]
        if B % M:
            raise ValueError(f"batch {B} not divisible by {M} microbatches")

        def stage_fn(w_local, x):
            s = jax.lax.axis_index(axis)
            xs = x.reshape((M, B // M) + x.shape[1:])
            mb_shape = xs.shape[1:]
            buf = jnp.zeros(mb_shape, x.dtype)      # activation entering me
            outs = jnp.zeros_like(xs)               # last stage's results
            fwd_perm = [(i, i + 1) for i in range(S - 1)]
            for t in range(M + S - 1):
                inject = xs[min(t, M - 1)]
                cur = jnp.where(s == 0, inject, buf)
                y = apply_range(w_local, cur)
                mb = t - (S - 1)
                if mb >= 0:
                    outs = outs.at[mb].set(y)       # valid on stage S-1 only
                if S > 1:
                    buf = jax.lax.ppermute(y, axis, fwd_perm)
            # replicate the last stage's collected outputs to every stage
            outs = jax.lax.psum(
                jnp.where(s == S - 1, outs, jnp.zeros_like(outs)), axis)
            return outs.reshape(x.shape)

        mapped = shard_map(
            stage_fn, mesh=mesh,
            in_specs=(P(axis), P()), out_specs=P(),
            check_vma=False)
        return mapped(w_stack, x)

    return fwd
