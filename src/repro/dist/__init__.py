"""Distributed-execution layer above ``repro.core``.

The paper's process model is "n processes on shared memory"; on a TPU mesh
the analogue is "n chips on a sharded address space".  This package maps the
logical-axis annotations every model/layer carries (see ``models/nn.py``)
onto concrete mesh axes, and supplies the fault-tolerance scaffolding a
production deployment needs when chips stall or drop:

- ``ctx``             — active sharding-rule context + ``shard_act``
- ``sharding``        — ``ShardingRules`` and the train/serve/dp rule tables
- ``tp``              — tensor-parallel block application (gspmd | manual)
- ``compression``     — int8 gradient compression with error feedback
- ``fault_tolerance`` — watchdog, straggler monitor, elastic remeshing
- ``pipeline``        — GPipe-style pipeline parallelism over the pod axis
- ``compat``          — shard_map/axis_size shims across jax versions

Submodules are imported lazily so that ``from repro.dist import ctx`` never
drags the model stack (``tp`` imports ``models.layers``) into lightweight
consumers like the checkpoint tooling.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("compat", "compression", "ctx", "fault_tolerance", "pipeline",
               "sharding", "tp")


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
