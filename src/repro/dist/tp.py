"""Tensor-parallel block application.

Two implementations behind one call signature, selected by
``cfg.tp_impl``:

- ``"gspmd"`` (baseline): run the plain ``models.layers`` block; TP comes
  from the weight shardings the active rules induce, with GSPMD inserting
  the collectives.
- ``"manual"``: Megatron-style shard_map blocks — column-parallel QKV /
  gate+up, row-parallel output projections, one explicit bf16 psum after
  attention and one after the MLP.

The manual region is fully manual over EVERY mesh axis (the pinned XLA
rejects partially-auto regions around the attention loops — see
``dist/compat.py``): the batch is explicitly split over the (pod, data)
axes when divisible and replicated otherwise.

The train-side manual path quietly falls back to gspmd whenever it cannot
apply (no active rules, no ``model`` axis, head counts / d_ff not divisible
by the TP width, or already inside a manual region that owns the model
axis) — CPU smoke tests therefore run the exact same numerics as the
single-device reference.  The DECODE-side gate is stricter about silence:
``decode_manual_unsupported`` returns a reason string for every refusal and
``serving/engine`` logs it — a production mesh can never lose the fused
path without a trace.  A model axis wider than ``n_kv`` is NOT a refusal at
decode: KV heads are replicated across the surplus width
(``decode_kv_rep``).

Decode side (the fused manual serve step in ``serving/engine.py``): this
module owns the gate (``decode_manual_tp``), the shard_map in_specs for the
stacked decode params (``decode_param_specs``), and the per-chip manual
projections that run INSIDE the engine's single manual region
(``mlp_decode_manual``, ``logits_decode_manual``).  Unlike the train gate, a
1-wide model axis still takes the fused path — head "shards" are then the
full head set, which gives the region single-process CPU test coverage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import ctx
from repro.dist.compat import shard_map
from repro.models import layers as L
from repro.models import nn


def _manual_tp(cfg, rules, *, need_ff: bool) -> int:
    """TP width when the manual path applies, else 0."""
    if cfg.tp_impl != "manual" or rules is None:
        return 0
    tp = rules.mesh.shape.get("model", 0)
    if tp <= 1 or "model" in ctx.current_manual_axes():
        return 0
    if cfg.n_q % tp or cfg.n_kv % tp:
        return 0
    if need_ff and cfg.d_ff % tp:
        return 0
    return tp


def _dp_axes(mesh, batch: int):
    """Mesh axes the batch dim is manually split over (empty -> replicated
    redundant compute on non-model axes, still correct)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if axes and batch % n == 0 else ()


def _bcast_spec(arr, batch: int, dp):
    """Spec for a per-token side input: batch-sharded when its leading dim
    is the batch, replicated otherwise (e.g. positions [1, S])."""
    if arr is None:
        return None
    if dp and arr.ndim >= 2 and arr.shape[0] == batch:
        return P(dp, *(None,) * (arr.ndim - 1))
    return P()


def _attn_specs(ap):
    specs = {"wq": P(None, "model", None), "wk": P(None, "model", None),
             "wv": P(None, "model", None), "wo": P("model", None, None)}
    if "bq" in ap:
        specs.update(bq=P("model", None), bk=P("model", None),
                     bv=P("model", None))
    return specs


def _attn_manual(cfg, rules, ap, ln, x, positions, window, mrope):
    """x [B,S,d] -> attention sublayer output (pre-residual), heads
    column-parallel over ``model``, row-parallel wo + psum."""
    mesh = rules.mesh
    B = x.shape[0]
    dp = _dp_axes(mesh, B)
    x_spec = P(dp, None, None) if dp else P()
    mr_spec = (P(None, dp, None) if (mrope is not None and dp
                                     and mrope.shape[1] == B)
               else (P() if mrope is not None else None))

    def fn(ap_l, ln_l, x, positions, mrope):
        xn = nn.rmsnorm(ln_l, x)
        q, k, v = L.attn_qkv(ap_l, xn)
        if mrope is not None and cfg.mrope_sections:
            q = L.apply_mrope(q, mrope, cfg.mrope_sections, cfg.rope_theta)
            k = L.apply_mrope(k, mrope, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        o = L.flash_attention(q, k, v, causal=True, window=window)
        y = L.attn_out(ap_l, o)
        return jax.lax.psum(y, "model")

    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(_attn_specs(ap), {"scale": P()}, x_spec,
                  _bcast_spec(positions, B, dp), mr_spec),
        out_specs=x_spec, check_vma=False)
    return mapped(ap, ln, x, positions, mrope)


def _mlp_manual(rules, mp, ln, x):
    """SwiGLU MLP, d_ff column-parallel, row-parallel wo + psum."""
    mesh = rules.mesh
    dp = _dp_axes(mesh, x.shape[0])
    x_spec = P(dp, None, None) if dp else P()

    def fn(mp_l, ln_l, x):
        y = L.mlp_apply(mp_l, nn.rmsnorm(ln_l, x))
        return jax.lax.psum(y, "model")

    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=({"wi_gate": P(None, "model"), "wi_up": P(None, "model"),
                   "wo": P("model", None)}, {"scale": P()}, x_spec),
        out_specs=x_spec, check_vma=False)
    return mapped(mp, ln, x)


# ---------------------------------------------------------------------------
# Decode-side manual TP (used by serving/engine's fused serve step).

def decode_kv_rep(cfg, tp: int) -> int:
    """KV-head replication factor for the fused decode region at TP width
    ``tp``: 1 when ``n_kv`` divides ``tp``'s complement (n_kv % tp == 0,
    plain head sharding), ``tp // n_kv`` when the mesh is WIDER than the KV
    head count (each KV head is replicated across the surplus width and
    every chip keeps exactly one head — e.g. kv=8 on the 16-wide production
    mesh, rep=2), and 0 when neither divides (unsupported shape)."""
    if tp <= 0:
        return 0
    if cfg.n_kv % tp == 0:
        return 1
    if cfg.n_kv and tp % cfg.n_kv == 0:
        return tp // cfg.n_kv
    return 0


def decode_manual_unsupported(cfg, rules):
    """Why the fused manual decode region cannot apply — None when it can.

    The gate is shape-only: ``tp_impl="manual"``, an active rule set with a
    ``model`` mesh axis not already manual, ``n_q`` divisible by the TP
    width, a valid KV replication factor (``decode_kv_rep``), and a
    divisible FFN (or expert) count.  tp == 1 is deliberately allowed (see
    module doc).  Family gating lives in ``serving/engine`` (ssm / encdec
    stay on the gspmd step)."""
    if cfg.tp_impl != "manual":
        return f"tp_impl={cfg.tp_impl!r} (not 'manual')"
    if rules is None:
        return "no active sharding rules"
    tp = rules.mesh.shape.get("model", 0)
    if tp < 1:
        return "mesh has no 'model' axis"
    if "model" in ctx.current_manual_axes():
        return "already inside a manual region owning 'model'"
    if cfg.n_q % tp:
        return f"n_q={cfg.n_q} not divisible by tp={tp}"
    if not decode_kv_rep(cfg, tp):
        return (f"n_kv={cfg.n_kv} neither divides nor is divided by "
                f"tp={tp} (no whole-head shard or replication)")
    if cfg.family == "moe":
        if cfg.num_experts % tp:
            return (f"num_experts={cfg.num_experts} not divisible by "
                    f"tp={tp}")
    elif cfg.d_ff % tp:
        return f"d_ff={cfg.d_ff} not divisible by tp={tp}"
    return None


def decode_manual_tp(cfg, rules) -> int:
    """TP width for the fused manual decode region, 0 when inapplicable
    (``decode_manual_unsupported`` gives the reason)."""
    if decode_manual_unsupported(cfg, rules) is not None:
        return 0
    return rules.mesh.shape["model"]


def decode_ssm_tp(cfg, tp: int) -> bool:
    """Whether the hybrid family's Mamba decode math shards over ``model``
    inside the fused region (ROADMAP item: it used to run as replicated
    redundant compute on every chip).  Shape gate: the per-head dims split
    over the existing ``ssm_inner``/``ssm_heads`` rules when the B/C
    streams are shared (``ssm_groups == 1`` — both assigned SSM archs) and
    the head count divides the TP width; otherwise the backbone stays
    replicated (still correct, just redundant).  ``tp == 1`` passes so
    single-process CPU tests cover the sharded code path (psum over a
    1-wide axis is the identity)."""
    if tp < 1 or cfg.ssm_state <= 0 or cfg.ssm_heads <= 0:
        return False                 # no SSM stack at all
    if cfg.ssm_groups != 1:
        return False                 # grouped B/C: head shard splits groups
    Hg = cfg.ssm_heads // cfg.ssm_groups
    return Hg % tp == 0 and cfg.d_inner % tp == 0


def _mamba_param_specs():
    """shard_map in_specs for STACKED mamba layer params (leading dim is the
    layer scan) inside the fused decode region, sharded per the
    ``ssm_inner``/``ssm_heads`` rules: per-head outputs column-parallel
    over ``model``, the shared B/C streams replicated, ``w_out``
    row-parallel."""
    return {
        "w_z": P(None, None, "model"),       # [L, d, di]
        "w_x": P(None, None, "model"),
        "w_bc": P(),                          # shared B/C streams (G == 1)
        "w_dt": P(None, None, "model"),      # [L, d, H]
        "conv_x_w": P(None, None, "model"),  # [L, W, di]
        "conv_x_b": P(None, "model"),
        "conv_bc_w": P(), "conv_bc_b": P(),
        "A_log": P(None, "model"), "dt_bias": P(None, "model"),
        "D": P(None, "model"),
        "norm": P(None, "model"),
        "w_out": P(None, "model", None),     # [L, di, d] row-parallel
    }


def decode_megastep_mode(cfg, rules, K: int) -> str:
    """Bookkeeping tag for the decode megastep (``serving/engine.
    make_serve_megastep``), recorded in dry-run artifacts next to
    ``decode_tp``: ``"scan-K{K}"`` when the K-token ``lax.scan`` dispatch
    applies (every family — the scan wraps whichever per-token body the
    gate above selects), ``"per-token"`` for K <= 1.  Dry-run's
    ``--expect-fused`` fails the build if an expected arch's decode cell
    records anything but a ``scan-`` tag — a regression back to per-token
    host dispatch can never land silently."""
    del cfg, rules  # the scan dispatch is family/mesh-independent today
    return f"scan-K{K}" if K > 1 else "per-token"


def decode_param_specs(cfg, params, *, vocab_sharded: bool,
                       kv_rep: int = 1, ssm_tp: bool = False):
    """shard_map in_specs (prefix pytree) for the fused manual decode region:
    stacked layer weights column/row-parallel over ``model`` (leading dim is
    the layer scan), everything else replicated.  ``vocab_sharded`` shards
    the untied lm_head over the vocab dim (logits all_gathered after).

    ``kv_rep > 1`` (KV heads replicated across the surplus model width):
    the K/V projections stay REPLICATED — each chip computes the full
    [B, n_kv, hd] K/V (n_kv·d·hd flops, noise at decode) and slices its own
    head in-region, which keeps the spec divisible without materialising a
    tiled weight copy per step.

    ``hybrid``: the ONE shared (attention + MLP) block is Megatron-sharded;
    the Mamba backbone shards its per-head dims over ``model`` when
    ``ssm_tp`` (gate ``decode_ssm_tp`` — the ssm_inner/ssm_heads rules) and
    runs replicated (redundant identical compute) otherwise."""
    kvw = P() if kv_rep > 1 else P(None, None, "model", None)
    kvb = P() if kv_rep > 1 else P(None, "model", None)
    if cfg.family == "hybrid":
        sh_attn = {"wq": P(None, "model", None),
                   "wk": P() if kv_rep > 1 else P(None, "model", None),
                   "wv": P() if kv_rep > 1 else P(None, "model", None),
                   "wo": P("model", None, None)}
        if "bq" in params["shared"]["attn"]:
            b1 = P() if kv_rep > 1 else P("model", None)
            sh_attn.update(bq=P("model", None), bk=b1, bv=b1)
        specs = {k: P() for k in params}
        specs["shared"] = {
            "attn": sh_attn, "ln1": P(), "ln2": P(),
            "mlp": {"wi_gate": P(None, "model"), "wi_up": P(None, "model"),
                    "wo": P("model", None)}}
        if ssm_tp:
            specs["layers"] = {"mamba": _mamba_param_specs(), "ln": P()}
        return specs
    h = P(None, None, "model", None)                 # [L, d, H, hd]
    attn = {"wq": h, "wk": kvw, "wv": kvw,
            "wo": P(None, "model", None, None)}      # [L, H, hd, d]
    if "bq" in params["layers"]["attn"]:
        attn.update(bq=P(None, "model", None), bk=kvb, bv=kvb)
    layer = {"attn": attn, "ln1": P(), "ln2": P()}
    if cfg.family == "moe":
        e = P(None, "model", None, None)             # [L, E, d|f, f|d]
        layer["moe"] = {"router": P(), "wi_gate": e, "wi_up": e, "wo": e}
    else:
        layer["mlp"] = {"wi_gate": P(None, None, "model"),
                        "wi_up": P(None, None, "model"),
                        "wo": P(None, "model", None)}
    specs = {k: P() for k in params}
    specs["layers"] = layer
    if vocab_sharded and "lm_head" in params:
        specs["lm_head"] = {"w": P(None, "model")}
    return specs


def mlp_decode_manual(mp, x):
    """SwiGLU MLP on a d_ff column shard + row-parallel wo; runs INSIDE an
    enclosing manual region that owns the model axis.  x [B, S, d]."""
    return jax.lax.psum(L.mlp_apply(mp, x), "model")


def logits_decode_manual(cfg, params, x, *, vocab_sharded: bool):
    """Read-out inside the manual region.  Tied embeddings stay replicated
    (the same table serves the lookup); an untied head is vocab-sharded over
    ``model`` with a tiled all_gather when the width divides."""
    if cfg.tie_embeddings:
        return nn.embed_logits(params["embed"], x)
    y = nn.dense(params["lm_head"], x)
    if vocab_sharded:
        y = jax.lax.all_gather(y, "model", axis=-1, tiled=True)
    return y


def attn_apply_tp(cfg, p, x, positions, *, window: int = 0,
                  mrope_positions=None):
    """Attention sublayer with residual: x + attn(rmsnorm(ln1, x)).

    ``p`` is the full layer param dict (needs "attn" and "ln1"); used by the
    MoE family whose FFN half is handled by ``models.moe``."""
    rules = ctx.current_rules()
    if not _manual_tp(cfg, rules, need_ff=False):
        h = L.self_attention(p["attn"], nn.rmsnorm(p["ln1"], x), positions,
                             cfg, window=window,
                             mrope_positions=mrope_positions)
        return x + h
    return x + _attn_manual(cfg, rules, p["attn"], p["ln1"], x, positions,
                            window, mrope_positions)


def block_apply_tp(cfg, p, x, positions, *, window: int = 0,
                   mrope_positions=None):
    """Full pre-norm (attn + MLP) block, TP'd per ``cfg.tp_impl``."""
    rules = ctx.current_rules()
    if not _manual_tp(cfg, rules, need_ff=True):
        return L.block_apply(p, x, positions, cfg, window=window,
                             mrope_positions=mrope_positions)
    x = x + _attn_manual(cfg, rules, p["attn"], p["ln1"], x, positions,
                         window, mrope_positions)
    x = x + _mlp_manual(rules, p["mlp"], p["ln2"], x)
    return x
