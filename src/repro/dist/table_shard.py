"""Hash-prefix sharding + lazy incremental resize for the page table.

This is the table-protocol half of the distributed page table (the
serving-facing routing facade lives in ``serving/sharded_table.py``):

* **Prefix routing** (``ShardManifest``) — the key space is partitioned by a
  *hash prefix of the sequence id*: ``prefix = top bits of
  hash(seq_id)``, and a manifest maps each of the ``2^prefix_bits`` prefix
  ranges to an owner shard (one shard per host group — the pod axis of the
  production meshes).  Routing by *sequence* (not by page key) means every
  page of a sequence lands on one owner shard, so admission control can be
  gated by the owner's headroom alone and the scheduler's no-ABORT proof
  restates per shard (see ``serving/sched/router.py``).  The manifest is
  plain data (JSON-serializable — it rides in the sharded checkpoint) and
  supports **reassignment**: losing a host group hands its prefix ranges to
  the survivors round-robin (``reassign``), which is all the routing layer
  needs for elastic recovery.

* **Lazy incremental resize** (``TableShard``) — the Gao/Groote/Hesselink
  protocol ("Lock-free dynamic hash tables with open addressing", PAPERS.md)
  adapted to the batched/quiescent table: instead of the Section 4.3
  stop-the-world rebuild, a grown shard holds TWO tables — ``old`` (the
  pre-grow table, frozen for inserts) and ``table`` (the fresh, larger
  one) — plus a **migration cursor**.  Buckets migrate out of ``old``

  - *on access*: inserts and deletes first migrate the touched keys
    (``migrate_keys``) — the paper's migrate-on-access rule;
  - *by cursor sweep*: each serving round migrates a bounded chunk of old
    cells (``sweep_migrate``), guaranteeing termination even for keys never
    touched again.

  Lookups stay **wait-free union reads** (new table first, then old —
  ``shard_find``): they never write, deviating from Gao et al. (who migrate
  on reads too) in favour of keeping the paper's wait-free read path; the
  cursor provides the progress a read-side helper would.  Every migrated
  entry leaves a **moved marker** behind: a TOMBSTONE in the old cell plus a
  per-entry bit carried in the old table's ``HashTable.meta`` leaf — the
  ProbeStrategy metadata path (PR 7); ``meta`` is empty for the
  metadata-free strategies, so the marker bitmask rides the existing pytree
  slot.  (Hopscotch already uses ``meta`` for neighborhood bitmaps; its
  tombstone-free delete — the cell reverts to EMPTY — *is* the moved marker
  there, and the bitmask is skipped.)

  Migration completes when ``old.num_keys == 0``; physical pages move WITH
  their keys, one bounded batch per round, via the ``MoveSet`` each
  migration step returns (the caller owns the pools — cell index IS the
  physical page, exactly as in the eager ``PageTable.rehash``).

Everything here is host-driven between megasteps (eager jax on small
batches) — the jitted decode megastep never sees a half-migrated table.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.core import encoding as E
from repro.core import hashing as H
from repro.obs import counters as OC

PREFIX_SEED = 0x50D5EED   # routing hash seed — independent of probe hashes
DEFAULT_PREFIX_BITS = 6   # 64 prefix ranges: fine-grained enough to respread
MIGRATE_CHUNK = 32        # old cells swept per migration service round


def seq_prefix(seq_ids, prefix_bits: int = DEFAULT_PREFIX_BITS):
    """Hash prefix of each sequence id: top ``prefix_bits`` bits of an
    independent hash — the routing key of the distributed table."""
    return H.hash_keys(jnp.asarray(seq_ids, jnp.uint32),
                       1 << prefix_bits, PREFIX_SEED)


@dataclasses.dataclass(frozen=True)
class ShardManifest:
    """Prefix-range -> owner-shard map.  ``owners[p]`` is the shard owning
    prefix ``p``; a shard with no prefixes is dead (lost / drained)."""
    prefix_bits: int
    owners: Tuple[int, ...]           # len == 2**prefix_bits

    @staticmethod
    def balanced(n_shards: int,
                 prefix_bits: int = DEFAULT_PREFIX_BITS) -> "ShardManifest":
        if n_shards < 1 or n_shards > (1 << prefix_bits):
            raise ValueError(f"n_shards={n_shards} not in [1, 2^{prefix_bits}]")
        owners = tuple(p % n_shards for p in range(1 << prefix_bits))
        return ShardManifest(prefix_bits, owners)

    @property
    def n_prefixes(self) -> int:
        return 1 << self.prefix_bits

    def live_shards(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.owners)))

    def owner_of_seq(self, seq_ids) -> np.ndarray:
        """Owner shard of each sequence id (host ints)."""
        pref = np.asarray(seq_prefix(seq_ids, self.prefix_bits))
        return np.asarray(self.owners, np.int32)[pref]

    def reassign(self, lost_shard: int) -> "ShardManifest":
        """Elastic recovery: hand the lost shard's prefix ranges to the
        survivors round-robin.  Prefixes owned by survivors are untouched,
        so in-flight sequences on surviving shards keep their owner."""
        survivors = [s for s in self.live_shards() if s != lost_shard]
        if not survivors:
            raise ValueError("cannot reassign: no surviving shards")
        owners = list(self.owners)
        nxt = 0
        for p, o in enumerate(owners):
            if o == lost_shard:
                owners[p] = survivors[nxt % len(survivors)]
                nxt += 1
        return ShardManifest(self.prefix_bits, tuple(owners))

    # -- serialization (rides in the sharded checkpoint) -----------------

    def to_json(self) -> str:
        return json.dumps({"prefix_bits": self.prefix_bits,
                           "owners": list(self.owners)})

    @staticmethod
    def from_json(s: str) -> "ShardManifest":
        d = json.loads(s)
        return ShardManifest(int(d["prefix_bits"]), tuple(d["owners"]))


@dataclasses.dataclass(frozen=True)
class MoveSet:
    """Physical page moves produced by one migration step: page data at
    old-table cell ``old_slots[i]`` must move to new-table cell
    ``new_slots[i]`` (local indices — the serving facade maps them to
    global pool slots via the shard's regions)."""
    old_slots: np.ndarray   # int32[n]
    new_slots: np.ndarray   # int32[n]

    @property
    def n(self) -> int:
        return int(self.old_slots.size)

    @staticmethod
    def empty() -> "MoveSet":
        z = np.zeros((0,), np.int32)
        return MoveSet(z, z)


def _marker_words(m: int) -> int:
    return (m + 31) // 32


@dataclasses.dataclass
class TableShard:
    """One shard of the distributed page table.  ``old is None`` = stable;
    otherwise a lazy resize is in flight (see module docstring)."""
    shard_id: int
    strategy: str
    table: BT.HashTable                 # current (post-grow) table
    old: Optional[BT.HashTable] = None  # migrating-from table
    cursor: int = 0                     # next old cell the sweep visits
    migrated: int = 0                   # entries moved so far (markers set)

    # -- state ----------------------------------------------------------

    @property
    def migrating(self) -> bool:
        return self.old is not None

    def n_cells(self) -> int:
        return BT.size(self.table)

    def live_pages(self) -> int:
        """Live keys across BOTH tables — each owns a physical page."""
        n = int(self.table.num_keys)
        if self.old is not None:
            n += int(self.old.num_keys)
        return n

    def free_cells(self) -> int:
        """Cells not spoken for in the CURRENT table.  During migration
        every un-migrated old key will eventually claim a new-table cell,
        so those cells are already committed: ``free = m_new - live_new -
        live_old``.  This keeps the forecaster's ``demand + safety + slack
        <= free_cells`` a no-ABORT proof *through* a migration — any
        interleaving of <= free_cells fresh inserts with migrations fits,
        because migrations consume exactly the live_old committed cells."""
        return BT.size(self.table) - self.live_pages()

    # -- construction ----------------------------------------------------

    @staticmethod
    def create(shard_id: int, m: int, seed: int = 0,
               strategy: str = "linear") -> "TableShard":
        return TableShard(shard_id=shard_id, strategy=strategy,
                          table=BT.create(m, seed=seed, strategy=strategy))

    # -- lazy resize ------------------------------------------------------

    def begin_migration(self, new_m: int,
                        seed: Optional[int] = None) -> "TableShard":
        """Start the lazy Section 4.3 grow: fresh empty table of ``new_m``
        cells becomes current; the previous table freezes as ``old`` (no
        new inserts land there) with a moved-marker bitmask threaded onto
        its ``meta`` leaf.  O(1) — no rehash, no page sweep; growth
        proceeds under traffic via migrate_keys/sweep_migrate."""
        if self.migrating:
            raise RuntimeError(
                f"shard {self.shard_id}: migration already in flight")
        if new_m < self.live_pages():
            raise ValueError(
                f"shard {self.shard_id}: new_m={new_m} below live set "
                f"{self.live_pages()}")
        old = self.table
        if old.meta.size == 0:   # metadata-free strategy: meta carries the
            old = old._replace(  # per-entry moved markers (PR 7 path)
                meta=jnp.zeros((_marker_words(BT.size(old)),), jnp.uint32))
        fresh = BT.create(new_m, seed=(int(self.table.seed) + 1
                                       if seed is None else seed),
                          strategy=self.strategy)
        return dataclasses.replace(self, table=fresh, old=old, cursor=0)

    def _mark_moved(self, old: BT.HashTable, slots: np.ndarray
                    ) -> BT.HashTable:
        if old.meta.size == 0 or slots.size == 0:   # hopscotch: EMPTY is
            return old                              # the marker already
        # host-side accumulating OR: two slots in one word must both land
        # (jnp .at[].set with duplicate indices keeps only one)
        meta = np.asarray(old.meta).copy()
        np.bitwise_or.at(meta, slots // 32,
                         np.uint32(1) << (slots.astype(np.uint32) % 32))
        return old._replace(meta=jnp.asarray(meta))

    def _migrate_active(self, keys, act) -> Tuple["TableShard", MoveSet]:
        """Migrate the active keys that are still in ``old``: insert into
        the current table, tombstone + mark the old cell, report the page
        moves.  The inner mechanic of both migration entry points."""
        assert self.old is not None
        keys = jnp.asarray(keys, jnp.uint32)
        found, old_slots = BT.find_batch(self.old, keys, act,
                                         strategy=self.strategy)
        mig = np.asarray(found & act)
        if not mig.any():
            return self, MoveSet.empty()
        mig_j = jnp.asarray(mig)
        table, ret = BT.insert_batch(self.table, keys, active=mig_j,
                                     strategy=self.strategy)
        if int(np.asarray((ret == 2) & mig_j).sum()):
            # begin_migration guarantees capacity; reaching here means the
            # caller grew below the live set — corruption, not overflow
            raise RuntimeError(
                f"shard {self.shard_id}: migration insert ABORTed — "
                f"new table smaller than the live set")
        _, new_slots = BT.find_batch(table, keys, active=mig_j,
                                     strategy=self.strategy)
        old, _ = BT.delete_batch(self.old, keys, active=mig_j,
                                 strategy=self.strategy)
        old_np = np.asarray(old_slots)[mig]
        old = self._mark_moved(old, old_np)
        moves = MoveSet(old_np.astype(np.int32),
                        np.asarray(new_slots)[mig].astype(np.int32))
        # host-plane telemetry (obs/counters.py): migration work is eager
        # and host-driven, so it reports on the host counter plane — the
        # derived probe count is one old-table find per candidate plus
        # insert + find + delete per migrated key
        OC.note_host("migration_moved", moves.n)
        OC.note_host("probe_steps",
                     int(np.asarray(act).sum()) + 3 * moves.n)
        shard = dataclasses.replace(self, table=table, old=old,
                                    migrated=self.migrated + moves.n)
        return shard._maybe_finish(), moves

    def migrate_keys(self, keys, active=None) -> Tuple["TableShard", MoveSet]:
        """Migrate-on-access: move the touched keys' buckets out of ``old``
        before an insert/delete lands.  No-op when stable."""
        if not self.migrating:
            return self, MoveSet.empty()
        keys = jnp.asarray(keys, jnp.uint32)
        act = (jnp.ones(keys.shape, bool) if active is None
               else jnp.asarray(active, bool))
        return self._migrate_active(keys, act)

    def sweep_migrate(self, chunk: int = MIGRATE_CHUNK
                      ) -> Tuple["TableShard", MoveSet]:
        """Cursor sweep: migrate the live keys in the next ``chunk`` old
        cells.  Bounded work per call; termination in ceil(m_old/chunk)
        calls regardless of access pattern."""
        if not self.migrating:
            return self, MoveSet.empty()
        assert self.old is not None
        m_old = BT.size(self.old)
        lo = self.cursor
        hi = min(lo + int(chunk), m_old)
        cells = self.old.table[lo:hi]
        is_key = E.dec_key(cells) != jnp.uint32(E.RESERVED_KEY)
        keys = jnp.where(is_key, E.dec_key(cells), jnp.uint32(0))
        shard, moves = self._migrate_active(keys, is_key)
        shard = dataclasses.replace(shard, cursor=hi)
        return shard._maybe_finish(), moves

    def _maybe_finish(self) -> "TableShard":
        if self.old is None:
            return self
        done_by_count = int(self.old.num_keys) == 0
        done_by_sweep = self.cursor >= BT.size(self.old)
        if done_by_count or done_by_sweep:
            if not done_by_count:
                # the sweep covered every cell, so nothing live can remain
                raise RuntimeError(
                    f"shard {self.shard_id}: sweep completed with "
                    f"{int(self.old.num_keys)} keys left in old")
            return dataclasses.replace(self, old=None, cursor=0)
        return self

    # -- operations (route through these, never at BT directly) ----------

    def find(self, keys, active=None
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Wait-free union read: (found, local_slot, in_old).  ``in_old``
        marks hits whose physical page still lives at the OLD table's cell
        (the serving facade maps those through the old region)."""
        keys = jnp.asarray(keys, jnp.uint32)
        found_n, slot_n = BT.find_batch(self.table, keys, active,
                                        strategy=self.strategy)
        if self.old is None:
            return found_n, slot_n, jnp.zeros(found_n.shape, bool)
        found_o, slot_o = BT.find_batch(self.old, keys, active,
                                        strategy=self.strategy)
        in_old = ~found_n & found_o
        return (found_n | found_o,
                jnp.where(found_n, slot_n, slot_o), in_old)

    def insert(self, keys, active=None
               ) -> Tuple["TableShard", jnp.ndarray, MoveSet]:
        """Insert into the CURRENT table (migrate-on-access first, so a
        re-inserted key can never be live in both tables).  Returns
        (shard', ret int32[B] — 1 inserted / 0 present / 2 ABORT, moves)."""
        keys = jnp.asarray(keys, jnp.uint32)
        act = (jnp.ones(keys.shape, bool) if active is None
               else jnp.asarray(active, bool))
        shard, moves = self.migrate_keys(keys, act)
        table, ret = BT.insert_batch(shard.table, keys, active=act,
                                     strategy=self.strategy)
        return dataclasses.replace(shard, table=table), ret, moves

    def delete(self, keys, active=None
               ) -> Tuple["TableShard", jnp.ndarray, MoveSet]:
        """Delete from wherever the key lives (migrate-on-access keeps the
        single-home invariant: after migrate, only the current table can
        hold it)."""
        keys = jnp.asarray(keys, jnp.uint32)
        act = (jnp.ones(keys.shape, bool) if active is None
               else jnp.asarray(active, bool))
        shard, moves = self.migrate_keys(keys, act)
        table, ret = BT.delete_batch(shard.table, keys, active=act,
                                     strategy=self.strategy)
        return dataclasses.replace(shard, table=table), ret, moves

    def migration_progress(self) -> Tuple[int, int]:
        """(entries migrated so far, entries still in old)."""
        left = 0 if self.old is None else int(self.old.num_keys)
        return self.migrated, left
