"""Version shims for jax APIs the codebase uses.

The repo targets the modern spellings (``jax.shard_map`` with
``axis_names=``/``check_vma=``, ``jax.lax.axis_size``); older jaxlibs (the
pinned CPU test toolchain is 0.4.x) expose the same machinery under
``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)`` and
``psum(1, axis)``.  Everything routes through here so call sites stay on one
spelling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.dist import ctx

try:  # jax >= 0.6: public API with axis_names/check_vma
    _MODERN = hasattr(jax, "shard_map")
except Exception:  # pragma: no cover
    _MODERN = False

if not _MODERN:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(f=None, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = True):
    """``jax.shard_map`` facade.

    ``axis_names``: the *manual* mesh axes (default: all of them); the rest
    stay auto (GSPMD).  The wrapped body runs inside ``ctx.manual_axes`` so
    ``shard_act`` knows which axes it must not constrain over.
    Usable directly or via ``functools.partial(shard_map, mesh=..., ...)``.
    """
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names=axis_names,
                                 check_vma=check_vma)
    manual = (frozenset(axis_names) if axis_names is not None
              else frozenset(mesh.axis_names))

    @functools.wraps(f)
    def body(*args):
        with ctx.manual_axes(manual):
            return f(*args)

    if _MODERN:
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=check_vma)
    auto = frozenset(mesh.axis_names) - manual
    return _legacy_shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             auto=auto)


def axis_size(name) -> int:
    """``jax.lax.axis_size`` fallback: the static size of a bound mapped
    axis (psum of 1 — folded to a constant at trace time)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)
