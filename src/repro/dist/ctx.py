"""Sharding-rules context: a dynamically-scoped rule set consulted by
``shard_act`` (activation sharding constraints) and by modules that pick a
collective strategy from the active rules (``models/moe.py``, ``dist/tp.py``).

The context is a plain Python stack manipulated during tracing — entering
``use_rules`` inside ``jit`` is fine because tracing is synchronous.  A
second stack tracks which mesh axes are *manual* in the innermost
``shard_map`` region (maintained by ``dist/compat.shard_map``): constraints
emitted inside such a region must not reference manual axes, so ``shard_act``
drops them from the spec instead of erroring.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import jax
from jax.sharding import NamedSharding

_RULES_STACK: list = []
_MANUAL_STACK: list = []


def current_rules():
    """The innermost active rule set (None when none, or entered with None)."""
    return _RULES_STACK[-1] if _RULES_STACK else None


@contextlib.contextmanager
def use_rules(rules) -> Iterator:
    """Make ``rules`` the active rule set for the dynamic extent of the
    block.  ``use_rules(None)`` explicitly *clears* the active rules (the
    single-device paths key off ``current_rules() is None``); the previous
    set is restored on exit."""
    _RULES_STACK.append(rules)
    try:
        yield rules
    finally:
        _RULES_STACK.pop()


def current_manual_axes() -> frozenset:
    """Union of mesh axes bound manually by enclosing shard_map regions."""
    out: frozenset = frozenset()
    for axes in _MANUAL_STACK:
        out = out | axes
    return out


@contextlib.contextmanager
def manual_axes(names) -> Iterator:
    """Record that ``names`` are manual inside the with-block (used by
    ``dist/compat.shard_map``; not normally called by user code)."""
    _MANUAL_STACK.append(frozenset(names))
    try:
        yield
    finally:
        _MANUAL_STACK.pop()


def shard_act(x, axes: tuple) -> jax.Array:
    """Apply ``jax.lax.with_sharding_constraint`` to activation ``x`` using
    the active rules; identity when no rules are active or the spec resolves
    fully replicated (CPU smoke tests run the exact same code).

    ``axes`` is a tuple of logical axis names (or None) per dim, e.g.
    ``("batch", "seq", None)``."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.spec(axes, x.shape, exclude=current_manual_axes())
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
