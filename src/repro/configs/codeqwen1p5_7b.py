"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32, i.e. MHA)
d_ff=13440 vocab=92416 — qwen1.5 arch.  [hf:Qwen/CodeQwen1.5-7B; hf]

32 heads divide the 16-way model axis cleanly (2/chip); d_ff 13440 = 16·840;
vocab 92416 = 16·5776 — no padding needed anywhere."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=13440, vocab_size=92416, head_dim=128,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=112, vocab_size=256, head_dim=16,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e4,
    )
