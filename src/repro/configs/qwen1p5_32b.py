"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40, i.e. MHA) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]

TP note: 40 heads don't divide the 16-way ``model`` axis; we pad heads to 48
(Megatron-style zero-head padding, documented in DESIGN.md §Sharding).  FLOP
accounting uses the true 40 heads, so the padding waste shows up in the
MODEL_FLOPS / HLO_FLOPs ratio of the roofline table rather than hiding.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
        d_ff=27392, vocab_size=152064, head_dim=128,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e6,
        pad_heads_to=48, pad_kv_to=48,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=160, vocab_size=256, head_dim=16,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e4,
    )
