"""Architecture configs (one module per assigned arch) + registry."""
from __future__ import annotations

import importlib

ARCHS = (
    "zamba2_1p2b",
    "qwen1p5_32b",
    "qwen2p5_32b",
    "gemma3_12b",
    "codeqwen1p5_7b",
    "seamless_m4t_large_v2",
    "granite_moe_1b_a400m",
    "qwen3_moe_235b_a22b",
    "mamba2_2p7b",
    "qwen2_vl_7b",
)

# public --arch ids (hyphen/dot form) -> module name
ARCH_IDS = {
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen1.5-32b": "qwen1p5_32b",
    "qwen2.5-32b": "qwen2p5_32b",
    "gemma3-12b": "gemma3_12b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def get_config(arch_id: str):
    """Full-size config for an --arch id (or module name)."""
    mod = ARCH_IDS.get(arch_id, arch_id.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod}").config()


def get_smoke_config(arch_id: str):
    """Reduced same-family config for CPU smoke tests."""
    mod = ARCH_IDS.get(arch_id, arch_id.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod}").smoke_config()
