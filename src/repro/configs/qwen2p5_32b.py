"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, GQA + QKV bias.  [hf:Qwen/Qwen2.5-0.5B family; hf]

TP note: 40 q-heads padded to 48 for the 16-way model axis; 8 KV heads are
GQA-replicated across TP (decode KV cache shards on the sequence dim via
flash-decoding instead — dist/sharding.py ``kv_seq`` rule)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=27648, vocab_size=152064, head_dim=128,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e6,
        pad_heads_to=48,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=192, vocab_size=256, head_dim=8,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e4,
    )
