"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local:global attention (1024-token sliding window on local
layers), 128k context.  head_dim=256 per the gemma3 family convention.
[hf:google/gemma-3-1b-pt family; unverified]

Sub-quadratic eligible: only every 6th layer holds full-length KV, so
long_500k decode is runnable (global layers use flash-decoding KV-seq
sharding; local layers hold a 1024-slot ring buffer)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
        d_ff=15360, vocab_size=262144, head_dim=256,
        qkv_bias=False, tie_embeddings=True, rope_theta=1e6,
        local_window=1024, pattern_local=5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        tie_embeddings=True, rope_theta=1e4,
        local_window=8, pattern_local=5,
    )
