"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(per expert) vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B
family; hf]

The scale driver of the fleet: ~235B total / ~22B active parameters.  128
experts shard 8-per-chip over the 16-way model axis (EP); KV (4 heads) is
GQA-replicated with flash-decoding KV-seq sharding at decode."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        d_ff=1536, vocab_size=151936, head_dim=128,
        qkv_bias=False, tie_embeddings=False, rope_theta=1e6,
        num_experts=128, experts_per_token=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=64, vocab_size=256, head_dim=8,
        tie_embeddings=False, rope_theta=1e4,
        num_experts=8, experts_per_token=2, moe_capacity_factor=100.0,
    )
