"""seamless-m4t-large-v2 [audio] — enc-dec, 24L (each side) d_model=1024 16H
(kv=16) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

The modality frontend (speech encoder feature extractor) is a STUB:
``input_specs()`` provides precomputed frame embeddings ``src_embeds``
(B, S//8, d) — the transformer backbone (conformer-less simplification) is
what we lower.  Decode shapes lower the *decoder* serve_step with
precomputed encoder output as cross-attention memory."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        num_layers=24, encoder_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=256206, head_dim=64,
        qkv_bias=False, tie_embeddings=True, rope_theta=1e4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec",
        num_layers=2, encoder_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=254, head_dim=16,
        tie_embeddings=True, rope_theta=1e4,
    )
