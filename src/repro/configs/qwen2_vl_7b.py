"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

The vision frontend (ViT + merger) is a STUB: ``input_specs()`` provides
precomputed patch embeddings (B, n_patch, d) that the backbone scatters into
image-placeholder token positions.  M-RoPE: rotary dims split into
(temporal, height, width) sections [16, 24, 24] over head_dim/2 = 64.

TP note: 28 q-heads pad to 32 for the 16-way model axis (2/chip); 4 KV heads
GQA-replicate with KV-seq flash-decoding shards at decode."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        pad_heads_to=32,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        qkv_bias=True, tie_embeddings=False, rope_theta=1e4,
        mrope_sections=(2, 3, 3),
    )
