"""mamba2-2.7b [ssm] — 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2·2560 = 5120; ssm head_dim 64 ⇒ 80 value heads (80 = 16·5, sharding
cleanly over the model axis); 1 B/C group (ngroups=1 in the paper's 2.7b).
Constant-size recurrent state ⇒ long_500k decode is O(1)/token."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        conv_width=4, ssm_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256,
        tie_embeddings=True,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
        conv_width=4, ssm_chunk=32,
    )
