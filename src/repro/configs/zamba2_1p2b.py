"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention blocks.
[arXiv:2411.15242; hf]

Realization (DESIGN.md §6): 38 Mamba2 blocks; ONE shared (attention + MLP)
block whose parameters are reused at every 6th position (6 invocations) —
the Zamba2 weight-sharing idea.  32 heads × 64 head_dim = 2048 = d_model.
Hybrid ⇒ long_500k runnable: SSM state is O(1); the shared-attention KV at
6 invocations uses flash-decoding KV-seq sharding."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        tie_embeddings=True, rope_theta=1e4,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
        conv_width=4, ssm_chunk=256,
        shared_attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        tie_embeddings=True, rope_theta=1e4,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
        conv_width=4, ssm_chunk=16,
        shared_attn_every=2,
    )
