"""Model/shape config dataclasses shared by all ten architectures.

``ModelConfig`` is a superset of the knobs the assigned families need; each
``configs/<arch>.py`` instantiates the exact published numbers.  ``SHAPES``
defines the four assigned input-shape sets; ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins for every model input of a (config, shape)
cell — weak-type-correct, shardable, no device allocation (the multi-pod
dry-run lowers against these).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int               # 0 for attention-free
    num_kv_heads: int
    d_ff: int                    # per-expert width for MoE
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 1e6
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1          # B/C groups (like GQA for SSM)
    conv_width: int = 4
    ssm_chunk: int = 256         # SSD chunk length
    # --- hybrid (zamba2): shared attention block every k SSM blocks ---
    shared_attn_every: int = 0
    # --- local/global (gemma3): pattern_local:1 global, window size ---
    local_window: int = 0
    pattern_local: int = 0       # e.g. 5 -> 5 local then 1 global
    # --- enc-dec (seamless) ---
    encoder_layers: int = 0
    # --- vlm (qwen2-vl M-RoPE) ---
    mrope_sections: Tuple[int, ...] = ()
    # --- numerics / padding ---
    dtype: str = "bfloat16"
    pad_heads_to: int = 0        # Megatron-style head padding for TP
    pad_kv_to: int = 0
    # dry-run only: fully unroll the layer scan so per-layer collectives and
    # matmuls appear xL in the partitioned HLO (XLA cost analysis counts a
    # while body once). Training/serving keep the rolled scan (small HLO).
    unroll_layers: bool = False
    # TP implementation: "gspmd" (baseline) or "manual" (shard_map blocks
    # with explicit bf16 psums — §Perf iteration, see dist/tp.py)
    tp_impl: str = "gspmd"
    # decode KV pool dtype: "bfloat16" (baseline) or "int8" (per-token
    # quantized — §Perf iteration, see serving/paged.py)
    kv_cache_dtype: str = "bfloat16"
    # paged-decode per-chip page-capacity factor over the uniform share
    page_capacity_factor: float = 2.0
    # decode attention as ONE fused Pallas dispatch that walks the raw
    # incremental block table in-kernel with double-buffered page DMA
    # (kernels/fused_decode) instead of the two-dispatch slots+compact →
    # attend path.  Gated per path by serving/engine._fused_kernel_reason;
    # a fallback is always logged + surfaced in dryrun meta, never silent.
    fused_kernel: bool = False
    # page-allocator probe strategy: "linear" (the paper's algorithm),
    # "robinhood" (displacement-ordered claims) or "hopscotch"
    # (neighborhood bitmaps, tombstone-free deletes) — see
    # core/probe_strategies.py.  The strategy SEMANTICS always hold; paths
    # a strategy cannot accelerate (the Pallas probe kernel assumes the
    # linear scan) degrade to the jnp oracle, gated by
    # serving/engine._probe_strategy_reason: logged + surfaced in dryrun
    # meta via engine.fallback_report, never silent.
    probe_strategy: str = "linear"

    # on-device telemetry counter plane (obs/counters.py): when True,
    # make_decode_state adds a ``counters`` pytree leaf and the serve step
    # accumulates probe/page/abort/token counts in-graph; they ride the
    # megastep scan and are read out at the existing once-per-K host sync,
    # so instrumentation adds ZERO extra device syncs.  When False the leaf
    # is never created and the compiled program is bitwise-identical to the
    # pre-telemetry one (identity fast path, pinned by tests/test_obs.py).
    telemetry: bool = False

    @property
    def scan_unroll(self) -> int:
        return self.num_layers if self.unroll_layers else 1

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def n_q(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def n_kv(self) -> int:
        return self.pad_kv_to or self.num_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / local-global attention."""
        return self.family in ("ssm", "hybrid") or self.pattern_local > 0

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count N (for 6·N·D model FLOPs)."""
        d, V = self.d_model, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            return emb + self.num_layers * _mamba2_block_params(self)
        if self.family == "hybrid":
            n_shared = self.num_layers // max(self.shared_attn_every, 1)
            shared = _attn_params(self) + _mlp_params(self, self.d_ff) + 2 * d
            return (emb + self.num_layers * _mamba2_block_params(self)
                    + shared)  # shared block counted once (it is shared)
        per_layer = _attn_params(self) + 2 * d
        if self.family == "moe":
            per_layer += (self.num_experts * _mlp_params(self, self.d_ff)
                          + d * self.num_experts)  # router
        else:
            per_layer += _mlp_params(self, self.d_ff)
        n = emb + self.num_layers * per_layer
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            n += self.encoder_layers * (_attn_params(self)
                                        + _mlp_params(self, self.d_ff) + 2 * d)
            n += self.num_layers * (_attn_params(self) + d)
        return n

    def active_param_count(self) -> int:
        """N_active for MoE (experts_per_token of num_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, V = self.d_model, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = (_attn_params(self) + 2 * d
                     + self.experts_per_token * _mlp_params(self, self.d_ff)
                     + d * self.num_experts)
        return emb + self.num_layers * per_layer


def _attn_params(cfg: ModelConfig) -> int:
    if cfg.num_heads == 0:
        return 0
    d, hd = cfg.d_model, cfg.hd
    qo = 2 * d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    bias = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd if cfg.qkv_bias else 0
    return qo + kv + bias


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff  # SwiGLU: gate, up, down


def _mamba2_block_params(cfg: ModelConfig) -> int:
    d, di, N, G = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    in_proj = d * (2 * di + 2 * G * N + cfg.ssm_heads)
    conv = cfg.conv_width * (di + 2 * G * N)
    out = di * d
    extra = 2 * cfg.ssm_heads + di  # A, D, norm-ish
    return in_proj + conv + out + extra + d  # + rmsnorm


# ---------------------------------------------------------------------------
# Shapes.

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch, shape) a runnable cell?  (flag, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic attention (DESIGN.md §6)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                pages_per_seq: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every input of this (arch, shape) cell.

    train/prefill: full-sequence tokens (+ modality-frontend stubs).
    decode: one new token per sequence + KV-cache/state stand-ins are built by
    the engine (serving/engine.py) — here we provide the request batch.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = sds((B, S), i32)
        specs["labels"] = sds((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = sds((B, S), i32)
    else:  # decode: one token per sequence, cache of length S
        specs["tokens"] = sds((B, 1), i32)
        specs["positions"] = sds((B,), i32)
    if cfg.family == "encdec" and shape.kind != "decode":
        # audio frontend stub: precomputed frame embeddings (length S//8)
        specs["src_embeds"] = sds((B, max(S // 8, 1), cfg.d_model),
                                  cfg.activation_dtype())
    if cfg.family == "vlm" and shape.kind != "decode":
        # vision frontend stub: precomputed patch embeddings merged into the
        # token stream at image positions; M-RoPE 3D positions
        n_patch = 1024 if S >= 1024 else S // 2
        specs["patch_embeds"] = sds((B, n_patch, cfg.d_model),
                                    cfg.activation_dtype())
        specs["mrope_positions"] = sds((3, B, S), i32)
    if cfg.family == "vlm" and shape.kind == "decode":
        specs["mrope_positions"] = sds((3, B, 1), i32)
    return specs
