"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
(per expert) vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

vocab 49155 = 3·16385 is not divisible by the 16-way model axis; the embedding
pads to 49168 internally (logits over pad ids masked to -inf)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        qkv_bias=False, tie_embeddings=True, rope_theta=1e4,
        num_experts=32, experts_per_token=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=256, head_dim=16,
        tie_embeddings=True, rope_theta=1e4,
        num_experts=4, experts_per_token=2, moe_capacity_factor=100.0,
    )
