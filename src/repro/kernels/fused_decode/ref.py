"""The two-dispatch baseline the fused kernel replaces, composed verbatim:
dispatch 1 materializes the masked slot view of the block table (the same
elementwise read as ``serving/page_table.block_table_slots`` — duplicated
here so the kernel layer does not import the serving layer), dispatch 2
runs the baseline paged-attention kernel over it.  The fused kernel's
normalized output must be BITWISE identical to this composition."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention_kernel


def block_table_slots_ref(block_table, positions, *, page_size: int):
    """Masked slot view (== ``serving/page_table.block_table_slots``):
    -1 where the logical page is absent or past the live horizon."""
    max_pages = block_table.shape[1]
    logical = jnp.arange(max_pages, dtype=jnp.int32)
    live = logical[None, :] <= (positions[:, None] // page_size)
    return jnp.where(live & (block_table >= 0), block_table, -1)


def fused_decode_ref(q, k_pages, v_pages, block_table, positions, *,
                     scales=None, interpret: bool = False):
    """Separate probe + attention dispatches over the same raw inputs as
    ``fused_decode_kernel`` (block_table int32[B,MP] raw cache rows,
    positions int32[B] current decode position)."""
    PS = k_pages.shape[1]
    slots = block_table_slots_ref(block_table, positions, page_size=PS)
    lens = positions.astype(jnp.int32) + 1
    return paged_attention_kernel(q, k_pages, v_pages, slots, lens,
                                  scales=scales, interpret=interpret)
