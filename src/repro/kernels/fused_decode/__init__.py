from repro.kernels.fused_decode.ops import (fused_paged_attention,
                                            merge_fused_partials)
from repro.kernels.fused_decode.ref import (block_table_slots_ref,
                                            fused_decode_ref)
