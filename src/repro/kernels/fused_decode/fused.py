"""Pallas-TPU fused block-table-walk + paged-attention decode kernel.

One dispatch per decode token (per layer): for each (sequence, kv-head) lane
the kernel walks the *raw* incremental block table (scalar-prefetched int32
rows — the paper's wait-free lookup result, cached by
``page_table.alloc_step_incremental``), derives page liveness in-kernel
(``p·PS <= pos  and  bt[b,p] >= 0``), and computes flash-decoding attention
over exactly the live pages.  This absorbs the separate
``block_table_slots`` dispatch AND its HBM round trip (the two-dispatch
path materializes the masked slot view to HBM and re-reads it), and — the
structural win — it never DMAs a dead page: the baseline kernel's BlockSpec
index_map must clamp ``-1`` ids to page 0 and fetch anyway, so every
(sequence, head) pays ``MP`` page fetches regardless of length.

Page fetches are **double-buffered**: the async copy for page *i+1* is
issued before attention on page *i* starts computing (two VMEM buffer slots,
one DMA semaphore per slot per stream), so the table-walk/page-fetch latency
hides behind the dot products — SNIPPETS.md's ``Prefetch(hash)`` idiom
carried to the page pool.  Walking the table inside the kernel is safe
precisely because the paper's lookup is wait-free: a lookup never blocks and
never retries, so reading the block-table row at dispatch time is a
linearizable snapshot — there is no lock a stalled DMA could hold.

Grid: (B, KH) — the page loop is an in-kernel ``fori_loop`` (the pipeline
needs manual DMA control, so pages cannot be a grid dimension).  The f32
online-softmax update replicates ``paged_attention._pa_kernel`` op for op
(same ``dot_general`` shapes, same masking, same reciprocal-multiply
finish), so the fused kernel's normalized output is **bitwise identical**
to the two-dispatch baseline — asserted by tests/test_kernel_fused.py.

``partials=True`` skips the normalization and emits the per-chip
``(acc, m, l)`` triple consumed by ``serving/paged.merge_global`` — the
shape the fully-manual decode region needs (pages sharded over (pod, data):
each chip walks its *local* block table and the lse merge crosses chips).

int8 KV pools ride along: per-(token, head) bf16 scale sidecars are fetched
through the same double-buffered pipeline and dequantized in f32 before the
dot product, matching the (extended) baseline kernel's op order exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fused_kernel(bt_ref, pos_ref,           # scalar prefetch [B,MP], [B]
                  q_ref,                      # [1, 1, G, D]
                  k_hbm, v_hbm,               # ANY [NP, PS, KH, D]
                  *rest,
                  PS: int, G: int, D: int, MP: int, NP: int,
                  quantized: bool, partials: bool):
    if quantized:
        ks_hbm, vs_hbm = rest[:2]
        rest = rest[2:]
    if partials:
        o_ref, m_ref, l_ref = rest[:3]
        scratch = rest[3:]
    else:
        o_ref = rest[0]
        scratch = rest[1:]
    if quantized:
        kb, vb, ksb, vsb, sem, m_scr, l_scr, acc_scr = scratch
    else:
        kb, vb, sem, m_scr, l_scr, acc_scr = scratch

    b = pl.program_id(0)
    h = pl.program_id(1)
    pos = pos_ref[b]

    def need(p):
        """Page p contributes at least one valid token — the ONLY pages the
        kernel fetches (the two-dispatch baseline DMAs all MP)."""
        return (p * PS <= pos) & (bt_ref[b, p] >= 0)

    def start(p, slot):
        pid = jnp.clip(bt_ref[b, p], 0, NP - 1)   # clamp: address only
        pltpu.make_async_copy(k_hbm.at[pid, :, h], kb.at[slot],
                              sem.at[slot, 0]).start()
        pltpu.make_async_copy(v_hbm.at[pid, :, h], vb.at[slot],
                              sem.at[slot, 1]).start()
        if quantized:
            pltpu.make_async_copy(ks_hbm.at[pid, :, h], ksb.at[slot],
                                  sem.at[slot, 2]).start()
            pltpu.make_async_copy(vs_hbm.at[pid, :, h], vsb.at[slot],
                                  sem.at[slot, 3]).start()

    def wait(slot):
        pltpu.make_async_copy(k_hbm.at[0, :, 0], kb.at[slot],
                              sem.at[slot, 0]).wait()
        pltpu.make_async_copy(v_hbm.at[0, :, 0], vb.at[slot],
                              sem.at[slot, 1]).wait()
        if quantized:
            pltpu.make_async_copy(ks_hbm.at[0, :, 0], ksb.at[slot],
                                  sem.at[slot, 2]).wait()
            pltpu.make_async_copy(vs_hbm.at[0, :, 0], vsb.at[slot],
                                  sem.at[slot, 3]).wait()

    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

    # software pipeline: warm-up fetch for page 0, then each iteration
    # issues page p+1's copy BEFORE waiting on / computing page p
    @pl.when(need(0))
    def _warmup():
        start(0, 0)

    def body(p, _):
        slot = jax.lax.rem(p, 2)

        @pl.when((p + 1 < MP) & need(p + 1))
        def _prefetch_next():
            start(p + 1, 1 - slot)

        @pl.when(need(p))
        def _attend():
            wait(slot)
            tok = p * PS + jax.lax.broadcasted_iota(jnp.int32, (PS,), 0)
            valid = tok <= pos
            # --- identical f32 op order to paged_attention._pa_kernel ---
            q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
            k = kb[slot].astype(jnp.float32)               # [PS, D]
            v = vb[slot].astype(jnp.float32)
            if quantized:
                k = k * ksb[slot].astype(jnp.float32)[:, None]
                v = v * vsb[slot].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            s = s * (D ** -0.5)                            # [G, PS]
            s = jnp.where(valid[None, :], s, NEG_INF)
            m_prev = m_scr[...][:, 0]                      # [G]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            alpha = jnp.exp(m_prev - m_new)                # [G]
            pexp = jnp.exp(s - m_new[:, None])             # [G, PS]
            pexp = jnp.where(valid[None, :], pexp, 0.0)
            l_new = l_scr[...][:, 0] * alpha + jnp.sum(pexp, axis=1)
            acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
                pexp, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_scr[...] = m_new[:, None]
            l_scr[...] = l_new[:, None]
            acc_scr[...] = acc

        return 0

    jax.lax.fori_loop(0, MP, body, 0)

    if partials:
        o_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...][:, 0]
        l_ref[0, 0] = l_scr[...][:, 0]
    else:
        l = l_scr[...][:, 0]
        norm = jnp.where(l > 0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = (acc_scr[...] * norm[:, None]).astype(o_ref.dtype)


def fused_decode_kernel(q, k_pages, v_pages, block_table, positions, *,
                        scales=None, partials: bool = False,
                        interpret: bool = False):
    """q [B,QH,D]; pools [NP,PS,KH,D]; block_table int32[B,MP] RAW
    incremental cache rows (-1 absent — liveness is derived in-kernel from
    ``positions``, NOT pre-masked); positions int32[B] current decode
    position (attends tokens <= positions[b]).  ``scales``: optional
    (k_scales, v_scales) [NP,PS,KH] bf16 sidecars for int8 pools.

    Returns [B,QH,D] (q.dtype), or with ``partials=True`` the unnormalized
    per-chip triple (o f32 [B,KH,G,D], m f32 [B,KH,G], l f32 [B,KH,G])."""
    B, QH, D = q.shape
    NP, PS, KH, _ = k_pages.shape
    MP = block_table.shape[1]
    assert QH % KH == 0
    G = QH // KH
    q4 = q.reshape(B, KH, G, D)
    quantized = scales is not None

    qmap = lambda b, h, bt, pos: (b, h, 0, 0)
    in_specs = [
        pl.BlockSpec((1, 1, G, D), qmap),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    operands = [q4, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec(memory_space=pltpu.ANY)] * 2
        operands += [scales[0], scales[1]]

    if partials:
        out_specs = [pl.BlockSpec((1, 1, G, D), qmap),
                     pl.BlockSpec((1, 1, G), lambda b, h, bt, pos: (b, h, 0)),
                     pl.BlockSpec((1, 1, G), lambda b, h, bt, pos: (b, h, 0))]
        out_shape = [jax.ShapeDtypeStruct((B, KH, G, D), jnp.float32),
                     jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
                     jax.ShapeDtypeStruct((B, KH, G), jnp.float32)]
    else:
        out_specs = pl.BlockSpec((1, 1, G, D), qmap)
        out_shape = jax.ShapeDtypeStruct((B, KH, G, D), q.dtype)

    scratch = [pltpu.VMEM((2, PS, D), k_pages.dtype),
               pltpu.VMEM((2, PS, D), v_pages.dtype)]
    n_streams = 2
    if quantized:
        scratch += [pltpu.VMEM((2, PS), scales[0].dtype),
                    pltpu.VMEM((2, PS), scales[1].dtype)]
        n_streams = 4
    scratch += [pltpu.SemaphoreType.DMA((2, n_streams)),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    kernel = functools.partial(_fused_kernel, PS=PS, G=G, D=D, MP=MP, NP=NP,
                               quantized=quantized, partials=partials)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_table.astype(jnp.int32), positions.astype(jnp.int32), *operands)
    if partials:
        return out[0], out[1], out[2]
    return out.reshape(B, QH, D)
