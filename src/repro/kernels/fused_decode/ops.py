"""Jitted wrapper for the fused block-table-walk + paged-attention kernel,
with structural HBM byte accounting on eager calls (``kernels.stats``)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import stats as KS
from repro.kernels.fused_decode.fused import fused_decode_kernel
from repro.kernels.fused_decode.ref import fused_decode_ref


@functools.partial(jax.jit, static_argnames=("partials", "interpret",
                                             "use_kernel", "quantized"))
def _fused_impl(q, k_pages, v_pages, block_table, positions, scales, *,
                partials: bool, interpret: bool, use_kernel: bool,
                quantized: bool):
    del quantized  # only disambiguates the jit cache for scales=None
    if use_kernel:
        return fused_decode_kernel(q, k_pages, v_pages, block_table,
                                   positions, scales=scales,
                                   partials=partials, interpret=interpret)
    assert not partials, "the two-dispatch ref has no partials mode"
    return fused_decode_ref(q, k_pages, v_pages, block_table, positions,
                            scales=scales, interpret=interpret)


def _note_fused_bytes(q, k_pages, v_pages, block_table, positions, scales):
    """Structural accounting for ONE fused dispatch: the raw block-table
    rows are scalar-prefetched once (no materialized slot round trip), and
    only live pages — ``p·PS <= pos`` with a present table entry — are
    DMA'd, per kv head."""
    B, MP = block_table.shape
    _, PS, KH, D = k_pages.shape
    page_bytes = PS * D * (k_pages.dtype.itemsize + v_pages.dtype.itemsize)
    if scales is not None:
        page_bytes += PS * (scales[0].dtype.itemsize
                            + scales[1].dtype.itemsize)
    try:
        bt = np.asarray(block_table)
        pos = np.asarray(positions)
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return  # traced: byte counters only apply to eager replays
    live = np.arange(MP)[None, :] * PS <= pos[:, None]
    fetched = int(np.sum(live & (bt >= 0)))
    KS.note_bytes("probe_bytes", B * MP * 4)
    KS.note_bytes("attn_bytes", fetched * KH * page_bytes)


def fused_paged_attention(q, k_pages, v_pages, block_table, positions, *,
                          scales=None, partials: bool = False,
                          use_kernel: bool = True,
                          interpret: bool = False):
    """One-dispatch decode attention over the RAW incremental block table
    (see fused.py).  ``use_kernel=False`` routes to the two-dispatch
    baseline (``fused_decode_ref``) — the fused kernel's normalized output
    is bitwise identical to it.

    Returns [B,QH,D], or the unnormalized per-chip (o, m, l) triple for
    ``serving/paged.merge_global`` when ``partials=True``."""
    _note_fused_bytes(q, k_pages, v_pages, block_table, positions, scales)
    return _fused_impl(q, k_pages, v_pages, block_table, positions, scales,
                       partials=partials, interpret=interpret,
                       use_kernel=use_kernel, quantized=scales is not None)


def merge_fused_partials(o, m, l):
    """Single-dispatch finish of the partials triple — identical math to
    ``serving/paged.merge_global`` with no mesh axes (normalize only).
    Mostly for tests: the engine always merges across chips."""
    return o / jnp.maximum(l, 1e-20)[..., None]
