"""Pallas-TPU wait-free probe-lookup kernel.

TPU adaptation of the paper's lookup path (DESIGN.md §2): sequential linear
probing touches one cache line per lookup; the TPU analog is one *VMEM tile*
per lookup batch.  Keys are pre-sorted by hash (in the XLA wrapper, ops.py),
so a tile of KT consecutive keys probes a narrow, contiguous region of the
table.  For each key tile the kernel DMAs **two consecutive table blocks**
(TB cells each) HBM→VMEM — the block containing the tile's first hash
position and its successor — via scalar-prefetched block indices feeding the
BlockSpec index_map.

Each key then scans its probe window with vector compares out of VMEM.  TPU
constraint honored: dynamic slicing happens only on the *sublane* dimension
(the table lives in VMEM as [rows, 128] lanes); the intra-row offset is
handled by masking lanes before the first probe position instead of shifting
— no lane-dimension dynamic indexing.  Effective probe window per key:
129..256 cells (two 128-lane rows minus the lane offset).

Keys whose run extends past the resident window are reported *unresolved*
and fall back to the jnp oracle — at load factor 1-1/x the expected run
length is O(x^2) << 128 (Knuth / Theorem 21), so the fast path covers the
overwhelming majority; this mirrors the paper's expected-amortized-cost
structure.  Lookups remain wait-free: no writes, no data-dependent retries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import encoding as E

LANES = 128
DEFAULT_TB = 2048   # table block (cells) resident in VMEM per tile
DEFAULT_KT = 128    # keys per tile
BIG = 1 << 30  # python int: inlined as an immediate, not a captured const


def _probe_kernel(bstart_ref,            # scalar prefetch: int32[nt]
                  keys_ref,              # uint32[1, KT]
                  hv_ref,                # int32[1, KT]
                  tab0_ref,              # uint32[TB//128, 128] block b
                  tab1_ref,              # uint32[TB//128, 128] block b+1
                  found_ref,             # int32[1, KT]
                  slot_ref,              # int32[1, KT]
                  resolved_ref,          # int32[1, KT]
                  scratch_ref,           # uint32[2*TB//128, 128] VMEM
                  *, TB: int, KT: int, m: int):
    t = pl.program_id(0)
    base = bstart_ref[t] * TB
    rows_per_block = TB // LANES
    total_rows = 2 * rows_per_block

    # stage both table blocks contiguously
    scratch_ref[pl.ds(0, rows_per_block), :] = tab0_ref[...]
    scratch_ref[pl.ds(rows_per_block, rows_per_block), :] = tab1_ref[...]

    lane = jax.lax.broadcasted_iota(jnp.int32, (2, LANES), 1)
    rowi = jax.lax.broadcasted_iota(jnp.int32, (2, LANES), 0)
    lin = rowi * LANES + lane                      # probe-order linear index

    def body(k, _):
        key = keys_ref[0, k]
        hv = hv_ref[0, k]
        off = hv - base                            # >= 0 (keys sorted)
        in_window = off < 2 * TB - LANES           # else: unresolved
        row = jnp.clip(off // LANES, 0, total_rows - 2)
        win = scratch_ref[pl.ds(row, 2), :]        # [2, 128]
        # probe positions >= hv only
        gpos = row * LANES + lin                   # position within 2 blocks
        valid = gpos >= off
        target = (key << 2) | jnp.uint32(E.TAG_FINAL)
        hit = (win == target) & valid
        empty = (win == jnp.uint32(E.EMPTY)) & valid
        first_hit = jnp.min(jnp.where(hit, lin, BIG))
        first_empty = jnp.min(jnp.where(empty, lin, BIG))
        found = (first_hit < first_empty) & in_window
        done = ((first_hit < BIG) | (first_empty < BIG)) & in_window
        pos = base + row * LANES + first_hit
        pos = jnp.where(pos >= m, pos - m, pos)    # wrap (nb*TB == m)
        found_ref[0, k] = found.astype(jnp.int32)
        slot_ref[0, k] = jnp.where(found, pos, -1)
        resolved_ref[0, k] = done.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, KT, body, 0)


def probe_lookup_kernel(table, keys_sorted, hv_sorted, bstart, *,
                        TB: int = DEFAULT_TB, KT: int = DEFAULT_KT,
                        interpret: bool = False):
    """Launch over nt = len(keys)//KT tiles.

    table: uint32[m] with m % TB == 0 and m // TB >= 2 (wrap-safe).
    keys_sorted/hv_sorted: uint32/int32 [nt*KT] sorted by hv.
    bstart: int32[nt] = hv of each tile's first key // TB.
    Returns (found int32[nt*KT], slot int32[nt*KT], resolved int32[nt*KT]).
    """
    m = table.shape[0]
    assert m % TB == 0 and m // TB >= 2, (m, TB)
    nb = m // TB
    nt = keys_sorted.shape[0] // KT
    assert keys_sorted.shape[0] == nt * KT

    table2d = table.reshape(nb * (TB // LANES), LANES)
    keys2d = keys_sorted.reshape(nt, KT)
    hv2d = hv_sorted.reshape(nt, KT)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((TB // LANES, LANES), lambda t, s: (s[t], 0)),
            pl.BlockSpec((TB // LANES, LANES),
                         lambda t, s: ((s[t] + 1) % nb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((2 * (TB // LANES), LANES), jnp.uint32)],
    )
    kernel = functools.partial(_probe_kernel, TB=TB, KT=KT, m=m)
    found, slot, resolved = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nt, KT), jnp.int32),
            jax.ShapeDtypeStruct((nt, KT), jnp.int32),
            jax.ShapeDtypeStruct((nt, KT), jnp.int32),
        ],
        interpret=interpret,
    )(bstart, keys2d, hv2d, table2d, table2d)
    return found.reshape(-1), slot.reshape(-1), resolved.reshape(-1)
