"""Pallas-TPU wait-free probe-lookup kernel (software-pipelined).

TPU adaptation of the paper's lookup path (DESIGN.md §2): sequential linear
probing touches one cache line per lookup; the TPU analog is one *VMEM tile*
per lookup batch.  Keys are pre-sorted by hash (in the XLA wrapper, ops.py),
so a tile of KT consecutive keys probes a narrow, contiguous region of the
table.  For each key tile the kernel stages **two consecutive table blocks**
(TB cells each) HBM→VMEM — the block containing the tile's first hash
position and its successor.

The staging is a two-stage prefetch-ahead pipeline (Maier & Sanders: memory
latency, not instruction count, dominates open-addressing probes — exactly
what software pipelining hides): the table lives in HBM (``memory_space=
ANY``) and the kernel issues the async copies for tile *t+1*'s window
BEFORE probing tile *t*'s resident window, double-buffering two window
slots with one DMA semaphore per (slot, block).  The grid is sequential on
TPU, so slot ``t % 2`` is always started at step ``t-1`` (or the step-0
warm-up) and waited exactly once at step ``t`` — by then the copy has had a
full tile of probe compute to complete.  This replaces the previous
two-block-window BlockSpec design (where the pipeline depth was whatever
the Mosaic scheduler chose) with explicit prefetch-ahead reads.

Each key then scans its probe window with vector compares out of VMEM.  TPU
constraint honored: dynamic slicing happens only on the *sublane* dimension
(the table lives in VMEM as [rows, 128] lanes); the intra-row offset is
handled by masking lanes before the first probe position instead of shifting
— no lane-dimension dynamic indexing.  Effective probe window per key:
129..256 cells (two 128-lane rows minus the lane offset).

Keys whose run extends past the resident window are reported *unresolved*
and fall back to the jnp oracle — at load factor 1-1/x the expected run
length is O(x^2) << 128 (Knuth / Theorem 21), so the fast path covers the
overwhelming majority; this mirrors the paper's expected-amortized-cost
structure.  Lookups remain wait-free: no writes, no data-dependent retries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import encoding as E

LANES = 128
DEFAULT_TB = 2048   # table block (cells) resident in VMEM per tile
DEFAULT_KT = 128    # keys per tile
BIG = 1 << 30  # python int: inlined as an immediate, not a captured const


def _probe_kernel(bstart_ref,            # scalar prefetch: int32[nt]
                  keys_ref,              # uint32[1, KT]
                  hv_ref,                # int32[1, KT]
                  tab_hbm,               # uint32[nb*TB//128, 128] HBM (ANY)
                  found_ref,             # int32[1, KT]
                  slot_ref,              # int32[1, KT]
                  resolved_ref,          # int32[1, KT]
                  win_ref,               # uint32[2, 2*TB//128, 128] VMEM
                  sem,                   # DMA sem (2 slots, 2 blocks)
                  *, TB: int, KT: int, m: int):
    t = pl.program_id(0)
    nt = pl.num_programs(0)
    rpb = TB // LANES                              # rows per table block
    total_rows = 2 * rpb
    nb = m // TB

    def start(tile, slot):
        """Issue the two async block copies for ``tile``'s window into
        window slot ``slot`` (block b and its wrap-around successor)."""
        b0 = bstart_ref[tile]
        b1 = jax.lax.rem(b0 + 1, nb)
        pltpu.make_async_copy(tab_hbm.at[pl.ds(b0 * rpb, rpb), :],
                              win_ref.at[slot, pl.ds(0, rpb), :],
                              sem.at[slot, 0]).start()
        pltpu.make_async_copy(tab_hbm.at[pl.ds(b1 * rpb, rpb), :],
                              win_ref.at[slot, pl.ds(rpb, rpb), :],
                              sem.at[slot, 1]).start()

    # two-stage pipeline: warm-up fetch for tile 0; thereafter tile t issues
    # tile t+1's copies BEFORE waiting on (then probing) its own window
    @pl.when(t == 0)
    def _warmup():
        start(0, 0)

    @pl.when(t + 1 < nt)
    def _prefetch_next():
        start(t + 1, jax.lax.rem(t + 1, 2))

    slot = jax.lax.rem(t, 2)
    pltpu.make_async_copy(tab_hbm.at[pl.ds(0, rpb), :],
                          win_ref.at[slot, pl.ds(0, rpb), :],
                          sem.at[slot, 0]).wait()
    pltpu.make_async_copy(tab_hbm.at[pl.ds(0, rpb), :],
                          win_ref.at[slot, pl.ds(rpb, rpb), :],
                          sem.at[slot, 1]).wait()

    base = bstart_ref[t] * TB
    lane = jax.lax.broadcasted_iota(jnp.int32, (2, LANES), 1)
    rowi = jax.lax.broadcasted_iota(jnp.int32, (2, LANES), 0)
    lin = rowi * LANES + lane                      # probe-order linear index

    def body(k, _):
        key = keys_ref[0, k]
        hv = hv_ref[0, k]
        off = hv - base                            # >= 0 (keys sorted)
        in_window = off < 2 * TB - LANES           # else: unresolved
        row = jnp.clip(off // LANES, 0, total_rows - 2)
        win = win_ref[slot, pl.ds(row, 2), :]      # [2, 128]
        # probe positions >= hv only
        gpos = row * LANES + lin                   # position within 2 blocks
        valid = gpos >= off
        target = (key << 2) | jnp.uint32(E.TAG_FINAL)
        hit = (win == target) & valid
        empty = (win == jnp.uint32(E.EMPTY)) & valid
        first_hit = jnp.min(jnp.where(hit, lin, BIG))
        first_empty = jnp.min(jnp.where(empty, lin, BIG))
        found = (first_hit < first_empty) & in_window
        done = ((first_hit < BIG) | (first_empty < BIG)) & in_window
        pos = base + row * LANES + first_hit
        pos = jnp.where(pos >= m, pos - m, pos)    # wrap (nb*TB == m)
        found_ref[0, k] = found.astype(jnp.int32)
        slot_ref[0, k] = jnp.where(found, pos, -1)
        resolved_ref[0, k] = done.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, KT, body, 0)


def probe_lookup_kernel(table, keys_sorted, hv_sorted, bstart, *,
                        TB: int = DEFAULT_TB, KT: int = DEFAULT_KT,
                        interpret: bool = False):
    """Launch over nt = len(keys)//KT tiles.

    table: uint32[m] with m % TB == 0 and m // TB >= 2 (wrap-safe).
    keys_sorted/hv_sorted: uint32/int32 [nt*KT] sorted by hv.
    bstart: int32[nt] = hv of each tile's first key // TB.
    Returns (found int32[nt*KT], slot int32[nt*KT], resolved int32[nt*KT]).
    """
    m = table.shape[0]
    assert m % TB == 0 and m // TB >= 2, (m, TB)
    nb = m // TB
    nt = keys_sorted.shape[0] // KT
    assert keys_sorted.shape[0] == nt * KT

    table2d = table.reshape(nb * (TB // LANES), LANES)
    keys2d = keys_sorted.reshape(nt, KT)
    hv2d = hv_sorted.reshape(nt, KT)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # whole table in HBM
        ],
        out_specs=[
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
            pl.BlockSpec((1, KT), lambda t, s: (t, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, 2 * (TB // LANES), LANES), jnp.uint32),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
    )
    kernel = functools.partial(_probe_kernel, TB=TB, KT=KT, m=m)
    found, slot, resolved = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nt, KT), jnp.int32),
            jax.ShapeDtypeStruct((nt, KT), jnp.int32),
            jax.ShapeDtypeStruct((nt, KT), jnp.int32),
        ],
        interpret=interpret,
    )(bstart, keys2d, hv2d, table2d)
    return found.reshape(-1), slot.reshape(-1), resolved.reshape(-1)
