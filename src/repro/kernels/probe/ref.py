"""Pure-jnp oracle for the probe-lookup kernel.

The reference is the batched engine's ``find_batch`` (wait-free vectorized
probing).  The kernel must agree exactly on (found, slot) for every key."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import batched as BT
from repro.core import encoding as E


def probe_lookup_ref(table: jnp.ndarray, keys: jnp.ndarray, seed: int):
    """table: uint32[m] quiescent cells; keys: uint32[B].
    Returns (found bool[B], slot int32[B])."""
    ht = BT.HashTable(table=table, num_keys=jnp.int32(0),
                      num_tombs=jnp.int32(0), seed=jnp.int32(seed),
                      meta=jnp.zeros((0,), jnp.uint32))
    return BT.find_batch(ht, keys)
