"""Jitted wrapper for the probe-lookup kernel: sort-by-hash + tile +
scalar-prefetch launch + oracle fallback for unresolved keys."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import batched as BT
from repro.kernels import stats as KS
from repro.kernels.probe.probe import (DEFAULT_KT, DEFAULT_TB,
                                       probe_lookup_kernel)


def _sorted_tiles(ht: BT.HashTable, keys, *, TB: int, KT: int):
    """Sort keys by hash, pad to a whole number of KT-key tiles, and compute
    each tile's starting table block.  Returns (keys_s, hv_s, bstart, inv)
    where ``inv`` is the inverse permutation back to input order — an O(n)
    scatter (``zeros.at[order].set(iota)``), not a second O(n log n)
    argsort: ``order`` is already the permutation, inverting it is one
    scatter of iota."""
    B = keys.shape[0]
    hv = BT._hash(ht, keys).astype(jnp.int32)
    order = jnp.argsort(hv)
    inv = jnp.zeros((B,), jnp.int32).at[order].set(
        jnp.arange(B, dtype=jnp.int32))
    keys_s = keys[order]
    hv_s = hv[order]

    nt = -(-B // KT)  # ceil
    pad = nt * KT - B
    if pad:
        keys_s = jnp.concatenate([keys_s,
                                  jnp.broadcast_to(keys_s[-1:], (pad,))])
        hv_s = jnp.concatenate([hv_s, jnp.broadcast_to(hv_s[-1:], (pad,))])
    bstart = (hv_s[::KT] // TB).astype(jnp.int32)
    return keys_s, hv_s, bstart, inv


@functools.partial(jax.jit, static_argnames=("TB", "KT", "interpret",
                                             "use_kernel"))
def _probe_lookup_impl(ht: BT.HashTable, keys, *, TB: int, KT: int,
                       interpret: bool, use_kernel: bool):
    keys = jnp.asarray(keys, jnp.uint32)
    m = BT.size(ht)
    B = keys.shape[0]
    if not use_kernel or m % TB != 0 or m // TB < 2:
        return BT.find_batch(ht, keys)

    keys_s, hv_s, bstart, inv = _sorted_tiles(ht, keys, TB=TB, KT=KT)

    found_k, slot_k, resolved_k = probe_lookup_kernel(
        ht.table, keys_s, hv_s, bstart, TB=TB, KT=KT, interpret=interpret)
    found_k = found_k[:B][inv].astype(bool)
    slot_k = slot_k[:B][inv]
    resolved = resolved_k[:B][inv].astype(bool)

    # oracle fallback for the (rare) unresolved tail
    need_fb = ~resolved
    found_fb, slot_fb = BT.find_batch(ht, keys, active=need_fb)
    found = jnp.where(resolved, found_k, found_fb)
    slot = jnp.where(resolved, slot_k, slot_fb)
    return found, slot


def probe_lookup(ht: BT.HashTable, keys, *, TB: int = DEFAULT_TB,
                 KT: int = DEFAULT_KT, interpret: bool = False,
                 use_kernel: bool = True, strategy: str = "linear"):
    """Wait-free batched lookup via the Pallas kernel (with jnp fallback for
    unresolved keys).  Returns (found bool[B], slot int32[B]).

    Drop-in equivalent of ``batched.find_batch`` (the ref.py oracle).
    Eager calls account the kernel's structural HBM traffic — two TB-cell
    blocks of u32 staged per key tile — in ``kernels.stats``.

    The kernel walks the LINEAR probe run from the home block, so it serves
    exactly the strategies whose lookup scan is bitwise the linear one
    (``kernel_supported``: "linear", and "robinhood" — displacement only
    reorders claim priority, never the probe sequence).  Passing a strategy
    with a different lookup shape (hopscotch's neighborhood gather) raises:
    the page-table facade (``serving.page_table.PageTable``) gates this
    upstream and falls back to the jnp oracle instead.
    """
    if strategy != "linear":
        from repro.core.probe_strategies import get_strategy
        if not get_strategy(strategy).kernel_supported:
            raise ValueError(
                f"probe_lookup: strategy {strategy!r} does not probe in "
                f"linear order — use the strategy's find_batch (the facade "
                f"routes this automatically)")
    m = BT.size(ht)
    B = jnp.shape(keys)[0]
    if use_kernel and isinstance(m, int) and m % TB == 0 and m // TB >= 2:
        nt = -(-B // KT)
        KS.note_bytes("probe_bytes", nt * 2 * TB * 4)
    return _probe_lookup_impl(ht, keys, TB=TB, KT=KT, interpret=interpret,
                              use_kernel=use_kernel)


def resolved_fraction(ht: BT.HashTable, keys, **kw):
    """Diagnostic: fraction of keys served by the kernel fast path."""
    keys = jnp.asarray(keys, jnp.uint32)
    B = keys.shape[0]
    TB = kw.get("TB", DEFAULT_TB)
    KT = kw.get("KT", DEFAULT_KT)
    keys_s, hv_s, bstart, _ = _sorted_tiles(ht, keys, TB=TB, KT=KT)
    _, _, resolved = probe_lookup_kernel(ht.table, keys_s, hv_s, bstart,
                                         TB=TB, KT=KT,
                                         interpret=kw.get("interpret", False))
    # the first B sorted entries are exactly the B real keys (pads sit at
    # the tail); the mean is permutation-invariant
    return resolved[:B].mean()
