from repro.kernels.probe.ops import probe_lookup, resolved_fraction
from repro.kernels.probe.ref import probe_lookup_ref
