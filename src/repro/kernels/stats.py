"""HBM bytes-moved accounting for the decode kernels (machine-independent
perf counter, the kernel-layer twin of ``page_table.PROBE_STATS``).

The Pallas kernels cannot increment a host counter from inside the grid, so
the ops wrappers account *structurally*: from the concrete block table /
positions they compute exactly how many bytes each dispatch DMAs HBM->VMEM
(pages actually fetched, slot-index traffic, scale sidecars).  Only eager
calls count — under jit the operands are tracers and the note is skipped —
which is precisely what the ``*_bytes_per_token`` benchmarks want: a
deterministic host-side replay, never a wall-clock measurement.

Scoped the same way as ``PT.probe_stats_scope``: enter a scope, run the
dispatches, read the per-category byte counts before the scope exits.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

import jax

# bytes DMA'd HBM->VMEM by category:
#   probe_bytes — slot-index / block-table traffic (the probe side: table
#                 blocks for the probe kernel, block-table rows + the
#                 materialized slot round-trip for the attention dispatch)
#   attn_bytes  — K/V page payload (+ int8 scale sidecars)
KERNEL_STATS = {"probe_bytes": 0, "attn_bytes": 0}


def kernel_stats_reset() -> None:
    for k in KERNEL_STATS:
        KERNEL_STATS[k] = 0


@contextlib.contextmanager
def kernel_stats_scope() -> Iterator[dict]:
    """Scoped byte accounting: inside the ``with`` block the counters start
    at 0 and count only the scope's own (eager) dispatches; on exit the
    enclosing values are RESTORED exactly, so one bench can never bleed
    bytes into another.  Read the scoped counts from the yielded dict
    *before* the block exits; scopes nest."""
    outer = dict(KERNEL_STATS)
    kernel_stats_reset()
    try:
        yield KERNEL_STATS
    finally:
        KERNEL_STATS.update(outer)


def note_bytes(category: str, n) -> None:
    try:
        KERNEL_STATS[category] += int(n)
    except (TypeError, jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        pass  # traced: byte counters only apply to eager replays
