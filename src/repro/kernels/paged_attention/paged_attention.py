"""Pallas-TPU paged-attention decode kernel (flash-decoding over pages).

The physical-page indirection comes from the lock-free page table
(serving/page_table.py): the hash-table *slot* of key (seq, logical_page) IS
the physical page index, so the pool is addressed through scalar-prefetched
``page_ids`` feeding the K/V BlockSpec index_maps — one DMA per (seq,
kv-head, page) grid step, online-softmax accumulation in VMEM scratch.

Grid: (B, KH, MP), MP innermost (sequential on TPU; scratch persists across
the page loop).  Block shapes: q [1,1,G,D], K/V [1,PS,1,D] selected by
page_ids[b,p] — D should be a multiple of 128 and PS a multiple of 8 on real
hardware; interpret-mode tests use small shapes.

Pages past ``lens[b]`` or with id -1 are masked (index_map clamps to page 0;
the mask keeps the math exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(page_ids_ref, lens_ref,      # scalar prefetch [B,MP], [B]
               q_ref,                        # [1, 1, G, D]
               k_ref,                        # [1, PS, 1, D]
               v_ref,                        # [1, PS, 1, D]
               *rest,                        # [ks_ref, vs_ref,] o_ref, scratch
               PS: int, G: int, D: int, MP: int, quantized: bool = False):
    if quantized:                            # int8 pools: [1, PS, 1] bf16
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lens_ref[b]
    pid = page_ids_ref[b, p]
    base = p * PS
    tok = base + jax.lax.broadcasted_iota(jnp.int32, (PS,), 0)
    valid = (tok < length) & (pid >= 0)

    @pl.when(jnp.any(valid))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [PS, D]
        v = v_ref[0, :, 0].astype(jnp.float32)         # [PS, D]
        if quantized:                                  # dequant in f32
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (D ** -0.5)                            # [G, PS]
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_prev = m_scr[...][:, 0]                      # [G]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)                # [G]
        pexp = jnp.exp(s - m_new[:, None])             # [G, PS]
        pexp = jnp.where(valid[None, :], pexp, 0.0)
        l_new = l_scr[...][:, 0] * alpha + jnp.sum(pexp, axis=1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]
        l_scr[...] = l_new[:, None]
        acc_scr[...] = acc

    @pl.when(p == MP - 1)
    def _finish():
        l = l_scr[...][:, 0]
        norm = jnp.where(l > 0, 1.0 / jnp.maximum(l, 1e-30), 0.0)
        o_ref[0, 0] = (acc_scr[...] * norm[:, None]).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, page_ids, lens, *,
                           scales=None, interpret: bool = False):
    """q [B,QH,D]; pools [NP,PS,KH,D]; page_ids int32[B,MP]; lens int32[B];
    ``scales``: optional (k_scales, v_scales) [NP,PS,KH] bf16 sidecars for
    int8 pools (dequantized in f32 inside the kernel).  Returns [B,QH,D]."""
    B, QH, D = q.shape
    NP, PS, KH, _ = k_pages.shape
    MP = page_ids.shape[1]
    assert QH % KH == 0
    G = QH // KH
    q4 = q.reshape(B, KH, G, D)
    quantized = scales is not None

    def _kv_map(b, h, p, ids, ln):
        # clamp only for addressing; the kernel masks on the raw -1 sentinel
        return (jnp.clip(ids[b, p], 0, NP - 1), 0, h, 0)

    def _sc_map(b, h, p, ids, ln):
        return (jnp.clip(ids[b, p], 0, NP - 1), 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, p, ids, ln: (b, h, 0, 0)),
        pl.BlockSpec((1, PS, 1, D), _kv_map),
        pl.BlockSpec((1, PS, 1, D), _kv_map),
    ]
    operands = [q4, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, PS, 1), _sc_map)] * 2
        operands += [scales[0], scales[1]]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, MP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, ids, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_pa_kernel, PS=PS, G=G, D=D, MP=MP,
                               quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        interpret=interpret,
    )(page_ids.astype(jnp.int32), lens.astype(jnp.int32), *operands)
    return out.reshape(B, QH, D)
