"""Pure-jnp oracle for the paged-attention decode kernel."""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, page_ids, lens, *, scales=None):
    """Decode attention over paged KV.

    q:        [B, QH, D]      single query token per sequence
    k_pages:  [NP, PS, KH, D] physical key pool
    v_pages:  [NP, PS, KH, D] physical value pool
    page_ids: int32[B, MP]    physical page per (seq, logical page); -1 unused
    lens:     int32[B]        KV length per sequence
    scales:   optional (k_scales, v_scales) [NP, PS, KH] for int8 pools
    returns:  [B, QH, D]
    """
    B, QH, D = q.shape
    NP, PS, KH, _ = k_pages.shape
    MP = page_ids.shape[1]
    G = QH // KH

    safe_ids = jnp.clip(page_ids, 0, NP - 1)
    k = k_pages[safe_ids].reshape(B, MP * PS, KH, D)
    v = v_pages[safe_ids].reshape(B, MP * PS, KH, D)
    if scales is not None:
        k = (k.astype(jnp.float32)
             * scales[0][safe_ids].reshape(B, MP * PS, KH)
             .astype(jnp.float32)[..., None])
        v = (v.astype(jnp.float32)
             * scales[1][safe_ids].reshape(B, MP * PS, KH)
             .astype(jnp.float32)[..., None])
    pos = jnp.arange(MP * PS)[None, :]
    valid = (pos < lens[:, None]) & jnp.repeat(page_ids >= 0, PS, axis=1)

    qg = q.reshape(B, KH, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,blhd->bhgl", qg, kf) / jnp.sqrt(D)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    w = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgl,blhd->bhgd", w, vf)
    return out.reshape(B, QH, D).astype(q.dtype)
