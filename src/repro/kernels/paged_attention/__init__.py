from repro.kernels.paged_attention.ops import paged_attention, shard_heads
from repro.kernels.paged_attention.ref import paged_attention_ref
