"""Jitted wrapper for paged attention with a jnp fallback path.

``paged_attention(..., use_kernel=False)`` routes to the oracle — used on
meshes/dtypes where the kernel is not applicable and in the sharded
flash-decoding combine (dist/sp.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, page_ids, lens, *,
                    use_kernel: bool = True, interpret: bool = False):
    if use_kernel:
        return paged_attention_kernel(q, k_pages, v_pages, page_ids, lens,
                                      interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, page_ids, lens)
