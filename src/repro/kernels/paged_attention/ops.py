"""Jitted wrapper for paged attention with a jnp fallback path.

``paged_attention(..., use_kernel=False)`` routes to the oracle — used on
meshes/dtypes where the kernel is not applicable and in the sharded
flash-decoding combine (dist/sp.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, page_ids, lens, *,
                    use_kernel: bool = True, interpret: bool = False):
    if use_kernel:
        return paged_attention_kernel(q, k_pages, v_pages, page_ids, lens,
                                      interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, page_ids, lens)


def shard_heads(q, k_pages, v_pages, shard: int, n_shards: int):
    """Slice (q, k_pages, v_pages) to TP shard ``shard`` of ``n_shards``
    along the head dims — the per-shard view the fused manual decode region
    (serving/engine, ``tp_impl="manual"``) feeds this kernel per chip.

    GQA grouping is contiguous (q head h reads kv head h // G), so slicing
    both head dims by equal contiguous blocks keeps every query's kv head
    local to its shard: kernel(shard s) == kernel(full)[:, s·QH/n : (s+1)·
    QH/n] exactly.  Requires QH and KH divisible by ``n_shards``."""
    QH = q.shape[1]
    KH = k_pages.shape[2]
    if QH % n_shards or KH % n_shards:
        raise ValueError(f"heads not divisible: QH={QH} KH={KH} "
                         f"n_shards={n_shards}")
    qh, kh = QH // n_shards, KH // n_shards
    return (q[:, shard * qh:(shard + 1) * qh],
            k_pages[:, :, shard * kh:(shard + 1) * kh],
            v_pages[:, :, shard * kh:(shard + 1) * kh])
