"""Jitted wrapper for paged attention with a jnp fallback path.

``paged_attention(..., use_kernel=False)`` routes to the oracle — used on
meshes/dtypes where the kernel is not applicable and in the sharded
flash-decoding combine (dist/sp.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import stats as KS
from repro.kernels.paged_attention.paged_attention import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "quantized"))
def _paged_attention_impl(q, k_pages, v_pages, page_ids, lens, scales, *,
                          use_kernel: bool, interpret: bool,
                          quantized: bool):
    del quantized  # only disambiguates the jit cache for scales=None
    if use_kernel:
        return paged_attention_kernel(q, k_pages, v_pages, page_ids, lens,
                                      scales=scales, interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, page_ids, lens,
                               scales=scales)


def paged_attention(q, k_pages, v_pages, page_ids, lens, *, scales=None,
                    use_kernel: bool = True, interpret: bool = False):
    """Two-dispatch decode attention (the slot view in ``page_ids`` was
    materialized by a separate block-table dispatch).  Structural HBM bytes
    are accounted on eager calls (``kernels.stats``): the kernel's BlockSpec
    clamps dead ``-1`` ids to page 0 and fetches anyway, so every (seq,
    kv-head) lane pays all MP page DMAs, and the slot indices made one HBM
    round trip (written by the probe dispatch, re-read here)."""
    B, MP = page_ids.shape
    NP, PS, KH, D = k_pages.shape
    page_bytes = PS * D * (k_pages.dtype.itemsize + v_pages.dtype.itemsize)
    if scales is not None:
        page_bytes += PS * (scales[0].dtype.itemsize
                            + scales[1].dtype.itemsize)
    KS.note_bytes("probe_bytes", 2 * B * MP * 4)        # slot round trip
    KS.note_bytes("attn_bytes", B * KH * MP * page_bytes)
    return _paged_attention_impl(q, k_pages, v_pages, page_ids, lens,
                                 scales, use_kernel=use_kernel,
                                 interpret=interpret,
                                 quantized=scales is not None)


def shard_heads(q, k_pages, v_pages, shard: int, n_shards: int,
                kv_rep: int = 1):
    """Slice (q, k_pages, v_pages) to TP shard ``shard`` of ``n_shards``
    along the head dims — the per-shard view the fused manual decode region
    (serving/engine, ``tp_impl="manual"``) feeds this kernel per chip.

    GQA grouping is contiguous (q head h reads kv head h // G), so slicing
    both head dims by equal contiguous blocks keeps every query's kv head
    local to its shard: kernel(shard s) == kernel(full)[:, s·QH/n : (s+1)·
    QH/n] exactly.  Requires QH divisible by ``n_shards`` and KH divisible
    by ``n_shards`` — OR, when the shard count exceeds the KV head count,
    ``kv_rep = n_shards / KH`` > 1: each KV head is REPLICATED on ``kv_rep``
    consecutive shards (shard s keeps original head s // kv_rep), whose q
    slices partition that head's query group, so the same exact-slice
    identity holds."""
    QH = q.shape[1]
    KH = k_pages.shape[2]
    if kv_rep == 1:
        if QH % n_shards or KH % n_shards:
            raise ValueError(f"heads not divisible: QH={QH} KH={KH} "
                             f"n_shards={n_shards}")
        kh, k0 = KH // n_shards, shard * (KH // n_shards)
    else:
        if QH % n_shards or KH * kv_rep != n_shards:
            raise ValueError(f"invalid replication: QH={QH} KH={KH} "
                             f"n_shards={n_shards} kv_rep={kv_rep}")
        kh, k0 = 1, shard // kv_rep
    qh = QH // n_shards
    return (q[:, shard * qh:(shard + 1) * qh],
            k_pages[:, :, k0:k0 + kh],
            v_pages[:, :, k0:k0 + kh])
