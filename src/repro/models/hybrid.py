"""Zamba2-style hybrid: Mamba2 backbone + ONE shared (attention+MLP) block
whose parameters are reused every ``shared_attn_every`` layers.

38 = 6·6 + 2 for the full config: six groups of (6 mamba layers -> shared
attn block), then 2 trailing mamba layers.  Each *invocation* of the shared
block has its own KV cache at decode time (parameters shared, state not).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.ctx import shard_act
from repro.models import layers as L
from repro.models import nn
from repro.models import ssm


def num_shared_invocations(cfg) -> int:
    return cfg.num_layers // cfg.shared_attn_every


def mamba_decode_chunk(cfg, layer_params, states, x, lo: int, hi: int,
                       tp_axis: str | None = None):
    """One-token decode through mamba layers [lo, hi): x [B,1,d] ->
    (x', states' for the chunk).  Pure per-lane jnp.  The gspmd step runs
    it as-is; the fused manual-TP serve step passes ``tp_axis="model"``
    when ``dist/tp.decode_ssm_tp`` applies — params/state arrive sharded
    over ``ssm_inner``/``ssm_heads`` and each chip computes only its head
    slice (row-parallel out + RMS psum inside ``mamba_decode_step``) —
    and falls back to replicated redundant compute otherwise."""
    chunk_p = jax.tree.map(lambda t: t[lo:hi], layer_params)
    chunk_s = jax.tree.map(lambda t: t[lo:hi], states)

    def body(x, xs):
        lp, st = xs
        h, st2 = ssm.mamba_decode_step(
            lp["mamba"], nn.rmsnorm(lp["ln"], x), cfg, st, tp_axis=tp_axis)
        return x + h, st2

    x, s2 = jax.lax.scan(body, x, (chunk_p, chunk_s),
                         unroll=(hi - lo) if cfg.unroll_layers else 1)
    return x, s2


def _mamba_layer_init(key, cfg, dtype):
    p, a = ssm.mamba_init(key, cfg, dtype)
    pn, an = nn.norm_init(cfg.d_model, dtype)
    return {"mamba": p, "ln": pn}, {"mamba": a, "ln": an}


def init(cfg, key) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    dtype = cfg.activation_dtype()
    k_emb, k_m, k_s = jax.random.split(key, 3)
    pe, ae = nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype)
    stacked, axes = nn.stack_layer_params(
        k_m, cfg.num_layers, lambda k: _mamba_layer_init(k, cfg, dtype))
    psh, ash = L.block_init(k_s, cfg, dtype)    # the ONE shared block
    pn, an = nn.norm_init(cfg.d_model, dtype)
    return ({"embed": pe, "layers": stacked, "shared": psh,
             "final_norm": pn},
            {"embed": ae, "layers": axes, "shared": ash, "final_norm": an})


def _mamba_scan(cfg, stacked, x, remat: bool):
    def body(x, layer_p):
        h = ssm.mamba_forward(layer_p["mamba"],
                              nn.rmsnorm(layer_p["ln"], x), cfg)
        return shard_act(x + h, ("batch", "seq", None)), None
    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    unroll = jax.tree.leaves(stacked)[0].shape[0] if cfg.unroll_layers else 1
    x, _ = jax.lax.scan(fn, x, stacked, unroll=unroll)
    return x


def forward(cfg, params, tokens, *, remat: bool = False,
            last_only: bool = False, **_):
    B, S = tokens.shape
    every = cfg.shared_attn_every
    n_inv = num_shared_invocations(cfg)
    x = nn.embed_lookup(params["embed"], tokens)
    x = shard_act(x, ("batch", "seq", None))
    positions = jnp.arange(S)[None, :]

    for g in range(n_inv):
        chunk = jax.tree.map(lambda t: t[g * every:(g + 1) * every],
                             params["layers"])
        x = _mamba_scan(cfg, chunk, x, remat)
        x = L.block_apply(params["shared"], x, positions, cfg)
        x = shard_act(x, ("batch", "seq", None))
    rem = cfg.num_layers - n_inv * every
    if rem:
        chunk = jax.tree.map(lambda t: t[n_inv * every:], params["layers"])
        x = _mamba_scan(cfg, chunk, x, remat)

    if last_only:
        x = x[:, -1:]
    x = nn.rmsnorm(params["final_norm"], x)
    logits = nn.embed_logits(params["embed"], x).astype(jnp.float32)
    return shard_act(logits, ("batch", "seq", "vocab")), jnp.float32(0.0)


def loss_fn(cfg, params, tokens, labels, *, remat: bool = True):
    logits, _ = forward(cfg, params, tokens, remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
