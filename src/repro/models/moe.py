"""Mixture-of-Experts MLP with expert parallelism over the ``model`` axis.

Dispatch strategy (DESIGN.md §5): activations are replicated across the
``model`` axis (they are sharded over ``data``/``pod`` only), so each chip
can gather the tokens destined for ITS local experts directly from its local
token set — dispatch needs **no all-to-all**; the only communication is the
same [T_local, d] all-reduce a dense TP MLP needs (combine psum).  This is
implemented as an explicit ``shard_map`` region so the collective schedule
is exactly what we wrote, not what GSPMD guesses.

Capacity: static per-chip per-expert capacity C = ceil(T_local·k/E · cf);
overflow tokens are dropped (gates renormalized over surviving experts) —
standard practice; the aux load-balance loss keeps overflow rare.  When no
mesh context is active (CPU smoke tests) the same code runs with a single
"shard" holding all experts.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import ctx
from repro.dist.compat import axis_size, shard_map
from repro.models import nn

def moe_init(key, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": nn._truncnorm(ks[0], (d, E), s_in, jnp.float32),
        "wi_gate": nn._truncnorm(ks[1], (E, d, f), s_in, dtype),
        "wi_up": nn._truncnorm(ks[2], (E, d, f), s_in, dtype),
        "wo": nn._truncnorm(ks[3], (E, f, d), s_out, dtype),
    }
    a = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "mlp_shard"),
        "wi_up": ("experts", "embed", "mlp_shard"),
        "wo": ("experts", "mlp_shard", "embed"),
    }
    return p, a


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int(math.ceil(T * k / E * factor))
    return min(T, max(8, -(-c // 8) * 8))


# Router snap grid (numerics): bf16 reduction-order noise across shardings
# perturbs the router input by ~1e-3, so raw top_k can pick DIFFERENT
# experts per sharding for near-tie logits — a full expert flip, i.e. O(1)
# logits drift from O(eps) numeric noise.  Snapping the scores to a coarse
# grid and breaking ties by expert index makes the selection a step
# function with margins far wider than the noise: shardings disagree only
# when a score sits within eps of a grid edge.  The snap is applied to the
# raw LOGITS (O(1) scale regardless of E) — never to the softmax probs,
# whose ~1/E magnitude would collapse every expert into one grid cell at
# production expert counts (E=128 -> probs ~0.008 << any useful grid).
ROUTER_SNAP_GRID = 1.0 / 64.0


def _router_top_k(logits, probs, k: int, E: int):
    """Deterministic, sharding-robust expert selection: top-k of the
    grid-snapped router logits with a lower-expert-index tie-break; gate
    values still come from the exact probabilities."""
    snapped = jnp.round(logits / ROUTER_SNAP_GRID)        # [T,E] small ints
    idx = jnp.arange(E, dtype=jnp.float32)
    _, ids = jax.lax.top_k(snapped * (E + 1.0) - idx[None, :], k)
    gates = jnp.take_along_axis(probs, ids, axis=-1)      # [T,k]
    return gates, ids


def _moe_local(x, router, wig, wiu, wo, *, k: int, E: int, E_local: int,
               e_offset, C: int):
    """Per-chip MoE: x [T,d] local tokens (replicated over model axis),
    expert weights local [E_local,...].  Returns (partial y [T,d], aux)."""
    T, d = x.shape
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)               # [T,E]
    gates, ids = _router_top_k(logits, probs, k, E)       # [T,k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                          # [E]
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = E * jnp.sum(me * ce)

    my_e = e_offset + jnp.arange(E_local)                 # [E_local]
    match = ids[None, :, :] == my_e[:, None, None]        # [E_local,T,k]
    sel = jnp.any(match, axis=-1)                         # [E_local,T]
    gate_e = jnp.sum(jnp.where(match, gates[None], 0.0), axis=-1)
    pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1   # [E_local,T]
    keep = sel & (pos < C)
    slot = jnp.where(keep, pos, C)                        # C = trash slot

    def scatter_tokens(slot_e, keep_e):
        buf = jnp.zeros((C + 1, d), x.dtype).at[slot_e].set(
            jnp.where(keep_e[:, None], x, 0))
        src = jnp.full((C + 1,), T, jnp.int32).at[slot_e].set(
            jnp.where(keep_e, jnp.arange(T), T))
        return buf[:C], src[:C]

    buf, src = jax.vmap(scatter_tokens)(slot, keep)       # [E_local,C,d]

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wig)) * \
        jnp.einsum("ecd,edf->ecf", buf, wiu)
    out = jnp.einsum("ecf,efd->ecd", h, wo)               # [E_local,C,d]

    gate_buf = jnp.take_along_axis(
        gate_e, jnp.minimum(src, T - 1), axis=1) * (src < T)  # [E_local,C]
    y = jnp.zeros((T + 1, d), jnp.float32).at[src.reshape(-1)].add(
        (out * gate_buf[..., None]).astype(jnp.float32).reshape(-1, d),
        mode="drop")
    return y[:T].astype(x.dtype), aux


def moe_apply(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,d] -> (y [B,S,d], aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    rules = ctx.current_rules()
    ep = (rules is not None and "model" in rules.mesh.shape
          and rules.axis_for("experts", E) is not None
          and E % rules.mesh.shape["model"] == 0)
    if rules is None:
        # single-shard path (smoke tests / tiny meshes)
        y, aux = _moe_local(x.reshape(B * S, d), p["router"], p["wi_gate"],
                            p["wi_up"], p["wo"], k=k, E=E, E_local=E,
                            e_offset=0,
                            C=_capacity(B * S, k, E, cfg.moe_capacity_factor))
        return y.reshape(B, S, d), aux
    if not ep:
        # DP mapping (§Perf): tokens sharded over EVERY axis, all experts
        # local (weights FSDP-gathered per layer by GSPMD outside) — no
        # dispatch communication at all.
        mesh = rules.mesh
        all_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.shape)
        n_all = 1
        for a in all_axes:
            n_all *= mesh.shape[a]
        bspec = all_axes if B % n_all == 0 else None
        B_l = B // n_all if bspec else B
        C = _capacity(B_l * S, k, E, cfg.moe_capacity_factor)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(bspec, None, None), P(), P(), P(), P()),
            out_specs=(P(bspec, None, None), P()),
            check_vma=False)
        def _dp(x_l, router, wig, wiu, wo):
            Bl = x_l.shape[0]
            y, aux = _moe_local(x_l.reshape(Bl * S, d), router, wig, wiu,
                                wo, k=k, E=E, E_local=E, e_offset=0, C=C)
            aux = jax.lax.pmean(aux, all_axes)
            return y.reshape(Bl, S, d), aux

        return _dp(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])

    mesh = rules.mesh
    tp = mesh.shape["model"]
    E_local = E // tp
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    serve = getattr(rules, "mode", "train") == "serve"
    # serve mode: tokens replicated; expert FFN width sharded over `data`
    f_sharded = serve and "data" in mesh.shape and \
        cfg.d_ff % mesh.shape["data"] == 0
    if serve:
        bspec, B_local = None, B
    else:
        bspec = dp_axes if B % dp == 0 else None
        B_local = B // dp if bspec else B
    T_local = B_local * S
    C = _capacity(T_local, k, E, cfg.moe_capacity_factor)
    f_spec = "data" if f_sharded else None
    psum_axes = ("model",) + (("data",) if f_sharded else ())

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(bspec, None, None), P(),
                  P("model", None, f_spec), P("model", None, f_spec),
                  P("model", f_spec, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False)
    def _sharded(x_l, router, wig, wiu, wo):
        Bl = x_l.shape[0]
        e_off = jax.lax.axis_index("model") * E_local
        y, aux = _moe_local(x_l.reshape(Bl * S, d), router, wig, wiu, wo,
                            k=k, E=E, E_local=E_local, e_offset=e_off, C=C)
        y = jax.lax.psum(y, psum_axes)
        aux = jax.lax.psum(aux, "model") / tp
        if dp_axes and not serve:
            aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(Bl, S, d), aux

    return _sharded(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])


def moe_decode_local(p, x, cfg) -> jnp.ndarray:
    """Per-chip MoE for the fused manual decode region (serving/engine.py):
    tokens replicated over every axis, experts sharded over ``model``
    (weights pre-sliced by the enclosing shard_map's in_specs), combine via
    one psum — the decode-mode manual projection.  Must run INSIDE a manual
    region that owns the model axis; x [B, S, d] -> y [B, S, d].  The aux
    load-balance loss is dropped (decode never trains the router)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    tp = axis_size("model")
    E_local = E // tp
    e_off = jax.lax.axis_index("model") * E_local
    C = _capacity(B * S, k, E, cfg.moe_capacity_factor)
    y, _ = _moe_local(x.reshape(B * S, d), p["router"], p["wi_gate"],
                      p["wi_up"], p["wo"], k=k, E=E, E_local=E_local,
                      e_offset=e_off, C=C)
    return jax.lax.psum(y.reshape(B, S, d), "model")


def moe_flops_per_token(cfg) -> int:
    """Active-expert matmul FLOPs per token (for roofline accounting)."""
    return 6 * cfg.experts_per_token * cfg.d_model * cfg.d_ff * 3
