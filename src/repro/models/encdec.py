"""Seamless-style encoder-decoder backbone.

Encoder: non-causal self-attention over precomputed frame embeddings (the
audio frontend is a stub per the assignment — ``input_specs`` provides
``src_embeds`` [B, S_src, d]).  Decoder: causal self-attention +
cross-attention over encoder memory.  Loss: teacher-forced next-token CE on
the target tokens.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.ctx import shard_act
from repro.models import layers as L
from repro.models import nn


def _enc_layer_init(key, cfg, dtype):
    return L.block_init(key, cfg, dtype)


def _dec_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p, a = L.block_init(k1, cfg, dtype)
    pc, ac = L.cross_attn_init(k2, cfg, dtype)
    pn, an = nn.norm_init(cfg.d_model, dtype)
    p.update({"cross": pc, "ln_cross": pn})
    a.update({"cross": ac, "ln_cross": an})
    return p, a


def init(cfg, key) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    dtype = cfg.activation_dtype()
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    pe, ae = nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype)
    enc, enc_a = nn.stack_layer_params(
        k_enc, cfg.encoder_layers, lambda k: _enc_layer_init(k, cfg, dtype))
    dec, dec_a = nn.stack_layer_params(
        k_dec, cfg.num_layers, lambda k: _dec_layer_init(k, cfg, dtype))
    pn, an = nn.norm_init(cfg.d_model, dtype)
    pn2, an2 = nn.norm_init(cfg.d_model, dtype)
    return ({"embed": pe, "encoder": enc, "decoder": dec,
             "enc_norm": pn, "final_norm": pn2},
            {"embed": ae, "encoder": enc_a, "decoder": dec_a,
             "enc_norm": an, "final_norm": an2})


def encode(cfg, params, src_embeds, *, remat: bool = False):
    x = shard_act(src_embeds, ("batch", "seq", None))
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, layer_p):
        h = L.self_attention(layer_p["attn"], nn.rmsnorm(layer_p["ln1"], x),
                             positions, cfg, causal=False)
        x = x + h
        x = x + L.mlp_apply(layer_p["mlp"], nn.rmsnorm(layer_p["ln2"], x))
        return shard_act(x, ("batch", "seq", None)), None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, _ = jax.lax.scan(fn, x, params["encoder"],
                        unroll=cfg.encoder_layers if cfg.unroll_layers else 1)
    return nn.rmsnorm(params["enc_norm"], x)


def decode_train(cfg, params, tokens, memory, *, remat: bool = False):
    x = nn.embed_lookup(params["embed"], tokens)
    x = shard_act(x, ("batch", "seq", None))
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, layer_p):
        h = L.self_attention(layer_p["attn"], nn.rmsnorm(layer_p["ln1"], x),
                             positions, cfg)
        x = x + h
        x = x + L.cross_attention(layer_p["cross"],
                                  nn.rmsnorm(layer_p["ln_cross"], x), memory)
        x = x + L.mlp_apply(layer_p["mlp"], nn.rmsnorm(layer_p["ln2"], x))
        return shard_act(x, ("batch", "seq", None)), None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, _ = jax.lax.scan(fn, x, params["decoder"], unroll=cfg.scan_unroll)
    return nn.rmsnorm(params["final_norm"], x)


def forward(cfg, params, tokens, *, src_embeds=None, remat: bool = False,
            last_only: bool = False, **_):
    """Teacher-forced forward -> (logits [B,S,V], aux)."""
    assert src_embeds is not None, "encdec requires src_embeds (stub frontend)"
    memory = encode(cfg, params, src_embeds, remat=remat)
    x = decode_train(cfg, params, tokens, memory, remat=remat)
    if last_only:
        x = x[:, -1:]
    logits = nn.embed_logits(params["embed"], x).astype(jnp.float32)
    return shard_act(logits, ("batch", "seq", "vocab")), jnp.float32(0.0)


def loss_fn(cfg, params, tokens, labels, *, src_embeds=None,
            remat: bool = True):
    logits, _ = forward(cfg, params, tokens, src_embeds=src_embeds,
                        remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
