"""Mamba2 LM (attention-free): embed -> scan of Mamba2 blocks -> logits."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.ctx import shard_act
from repro.models import nn
from repro.models import ssm


def _layer_init(key, cfg, dtype):
    p, a = ssm.mamba_init(key, cfg, dtype)
    pn, an = nn.norm_init(cfg.d_model, dtype)
    return {"mamba": p, "ln": pn}, {"mamba": a, "ln": an}


def init(cfg, key) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    dtype = cfg.activation_dtype()
    k_emb, k_layers = jax.random.split(key)
    pe, ae = nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype)
    stacked, axes = nn.stack_layer_params(
        k_layers, cfg.num_layers, lambda k: _layer_init(k, cfg, dtype))
    pn, an = nn.norm_init(cfg.d_model, dtype)
    return ({"embed": pe, "layers": stacked, "final_norm": pn},
            {"embed": ae, "layers": axes, "final_norm": an})


def forward(cfg, params, tokens, *, remat: bool = False,
            last_only: bool = False, **_):
    B, S = tokens.shape
    x = nn.embed_lookup(params["embed"], tokens)
    x = shard_act(x, ("batch", "seq", None))

    def body(x, layer_p):
        h = ssm.mamba_forward(layer_p["mamba"],
                              nn.rmsnorm(layer_p["ln"], x), cfg)
        return shard_act(x + h, ("batch", "seq", None)), None

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    x, _ = jax.lax.scan(fn, x, params["layers"], unroll=cfg.scan_unroll)
    if last_only:
        x = x[:, -1:]
    x = nn.rmsnorm(params["final_norm"], x)
    logits = nn.embed_logits(params["embed"], x).astype(jnp.float32)
    return shard_act(logits, ("batch", "seq", "vocab")), jnp.float32(0.0)


def loss_fn(cfg, params, tokens, labels, *, remat: bool = True):
    logits, _ = forward(cfg, params, tokens, remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
