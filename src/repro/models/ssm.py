"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

Training/prefill uses the SSD chunked algorithm (arXiv:2405.21060): within a
chunk of length Q everything is dense matmuls (MXU-friendly); across chunks a
small recurrent state h [B,G,Hg,P,N] is carried by ``lax.scan``.  Decode is
the O(1)/token recurrence.  The chunk loop is a scan (not unrolled), so HLO
stays small and the [Q,Q] intra-chunk score tensor is a bounded temp.

Tensor parallelism (Megatron-style, head-aligned): the in-projection is SPLIT
into z / x / BC / dt matrices so that per-head outputs (z, x, dt, A, D, norm,
conv_x) shard over the ``model`` axis while the shared B/C streams stay
replicated (G=1 for both assigned SSM archs); ``w_out`` is row-parallel
(XLA inserts the reduce-scatter/all-reduce).  Grouped B/C (``ssm_groups``)
is the SSM analog of GQA.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import nn


class MambaState(NamedTuple):
    """Decode-time recurrent state for one layer (stackable over layers)."""
    h: jnp.ndarray          # f32[B, G, Hg, P, N] SSD state
    conv_x: jnp.ndarray     # [B, W-1, di]        conv tail, x stream
    conv_bc: jnp.ndarray    # [B, W-1, 2*G*N]     conv tail, B/C streams


MAMBA_STATE_AXES = MambaState(
    h=("batch", None, "ssm_heads", None, None),
    conv_x=("batch", None, "ssm_inner"),
    conv_bc=("batch", None, None),
)


def mamba_init(key, cfg, dtype):
    d, di, N, G = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H = cfg.ssm_heads
    W = cfg.conv_width
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {
        "w_z": nn._truncnorm(ks[0], (d, di), s, dtype),
        "w_x": nn._truncnorm(ks[1], (d, di), s, dtype),
        "w_bc": nn._truncnorm(ks[2], (d, 2 * G * N), s, dtype),
        "w_dt": nn._truncnorm(ks[3], (d, H), s, dtype),
        "conv_x_w": nn._truncnorm(ks[4], (W, di), 0.5, dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": nn._truncnorm(ks[5], (W, 2 * G * N), 0.5, dtype),
        "conv_bc_b": jnp.zeros((2 * G * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": nn._truncnorm(ks[0], (di, d), 1.0 / math.sqrt(di), dtype),
    }
    a = {
        "w_z": ("embed", "ssm_inner"),
        "w_x": ("embed", "ssm_inner"),
        "w_bc": ("embed", None),
        "w_dt": ("embed", "ssm_heads"),
        "conv_x_w": ("conv", "ssm_inner"),
        "conv_x_b": ("ssm_inner",),
        "conv_bc_w": ("conv", None),
        "conv_bc_b": (None,),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "w_out": ("ssm_inner", "embed"),
    }
    return p, a


def _causal_conv(x, w, b, tail):
    """x [B,S,C]; w [W,C] depthwise causal conv; tail [B,W-1,C] history.
    Returns (y, new_tail)."""
    B, S, C = x.shape
    W = w.shape[0]
    xp = jnp.concatenate([tail, x], axis=1)               # [B, S+W-1, C]
    # depthwise conv as sum of W shifted scalings (W=4 — cheap, fusible)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(W))
    y = jax.nn.silu(y + b[None, None, :])
    new_tail = xp[:, S:, :]
    return y, new_tail


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int, h0=None):
    """SSD scan.  x [B,S,G,Hg,P]; dt [B,S,G,Hg] (softplus'd); A [G,Hg] (<0);
    Bm/Cm [B,S,G,N]; D [G,Hg].  Returns (y [B,S,G,Hg,P], h_fin [B,G,Hg,P,N]).
    """
    Bsz, S, G, Hg, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def to_chunks(t):
        return t.reshape((Bsz, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    xs, dts, Bs, Cs = map(to_chunks, (x, dt, Bm, Cm))
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def one_chunk(h, inp):
        xc, dtc, Bc, Cc = inp                # [B,Q,G,Hg,P], [B,Q,G,Hg], ...
        dA = dtc * A[None, None]             # [B,Q,G,Hg]
        A_cum = jnp.cumsum(dA, axis=1)
        A_last = A_cum[:, -1]                # [B,G,Hg]
        xdt = (xc * dtc[..., None]).astype(jnp.float32)
        # inter-chunk: carried state contribution
        y_inter = jnp.einsum("bqgn,bghpn->bqghp", Cc.astype(jnp.float32), h) \
            * jnp.exp(A_cum)[..., None]
        # intra-chunk: causal decay-weighted CB^T.  Mask BEFORE exp: masked
        # (j>i) entries have positive exponents that overflow to inf and
        # would poison the backward pass through where().
        scores = jnp.einsum("bign,bjgn->bijg", Cc, Bc,
                            preferred_element_type=jnp.float32)
        Ldec = A_cum[:, :, None] - A_cum[:, None, :]      # [B,i,j,G,Hg]
        Ldec = jnp.where(causal[None, :, :, None, None], Ldec, -1e30)
        M = jnp.exp(Ldec) * scores[..., None]
        y_intra = jnp.einsum("bijgh,bjghp->bighp", M, xdt)
        # state update
        decay_states = jnp.exp(A_last[:, None] - A_cum)   # [B,Q,G,Hg]
        S_chunk = jnp.einsum("bqgn,bqghp->bghpn", Bc.astype(jnp.float32),
                             xdt * decay_states[..., None])
        h_new = h * jnp.exp(A_last)[..., None, None] + S_chunk
        y = y_inter + y_intra + xc.astype(jnp.float32) * D[None, None, ..., None]
        return h_new, y.astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((Bsz, G, Hg, P, N), jnp.float32)
    h_fin, ys = jax.lax.scan(one_chunk, h0, (xs, dts, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, G, Hg, P)
    return y, h_fin


def _gate_norm_out(p, y, z, x_dtype, *, tp_axis=None, di_full=None):
    """Mamba2 gated RMSNorm + out projection.  y,z [B,S,di].

    ``tp_axis`` (inside a manual shard_map region): y/z/norm/w_out carry a
    LOCAL ``di`` shard — the RMS statistic is completed with a psum over the
    full inner width ``di_full`` and the row-parallel out projection psums
    its partial products (Megatron row-parallel over the SSM inner dim)."""
    y = y * jax.nn.silu(z.astype(jnp.float32))
    if tp_axis is None:
        var = jnp.mean(y * y, axis=-1, keepdims=True)
    else:
        var = jax.lax.psum(jnp.sum(y * y, axis=-1, keepdims=True),
                           tp_axis) / di_full
    y = (y * jax.lax.rsqrt(var + 1e-6)).astype(x_dtype) * p["norm"]
    if tp_axis is None:
        return jnp.einsum("bse,ed->bsd", y, p["w_out"])
    # row-parallel: accumulate the partial products in f32 and round ONCE
    # after the psum — rounding bf16 partials per shard would diverge from
    # the replicated path's single post-sum rounding, and the SSM
    # recurrence amplifies ulp-level drift across decode steps
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"],
                     preferred_element_type=jnp.float32)
    return jax.lax.psum(out, tp_axis).astype(y.dtype)


def mamba_forward(p, x, cfg, *, state: MambaState | None = None,
                  return_state: bool = False):
    """Full-sequence forward.  x [B,S,d] -> [B,S,d] (+ final MambaState)."""
    Bsz, S, d = x.shape
    di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    Hg = H // G

    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])

    tail_x = state.conv_x if state is not None else \
        jnp.zeros((Bsz, cfg.conv_width - 1, di), x.dtype)
    tail_bc = state.conv_bc if state is not None else \
        jnp.zeros((Bsz, cfg.conv_width - 1, 2 * G * N), x.dtype)
    xs, new_tail_x = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"], tail_x)
    bc, new_tail_bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"], tail_bc)

    x_ssm = xs.reshape(Bsz, S, G, Hg, P)
    Bm = bc[..., :G * N].reshape(Bsz, S, G, N)
    Cm = bc[..., G * N:].reshape(Bsz, S, G, N)
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"][None, None]).reshape(Bsz, S, G, Hg)
    A = -jnp.exp(p["A_log"]).reshape(G, Hg)
    h0 = state.h if state is not None else None

    y, h_fin = ssd_chunked(x_ssm, dtp, A, Bm, Cm, p["D"].reshape(G, Hg),
                           chunk=cfg.ssm_chunk, h0=h0)
    out = _gate_norm_out(p, y.reshape(Bsz, S, di).astype(jnp.float32), z,
                         x.dtype)
    if return_state:
        return out, MambaState(h=h_fin, conv_x=new_tail_x,
                               conv_bc=new_tail_bc)
    return out


def mamba_decode_step(p, x, cfg, state: MambaState, *,
                      tp_axis: str | None = None
                      ) -> Tuple[jnp.ndarray, MambaState]:
    """One-token decode.  x [B,1,d] -> ([B,1,d], state').

    ``tp_axis``: run on a per-head SHARD of the inner dim inside a manual
    shard_map region that owns that mesh axis (the fused manual-TP decode,
    serving/engine).  The per-head params (w_z/w_x/w_dt/conv_x/A/D/norm) and
    the recurrent state arrive column-sharded over ``ssm_inner``/
    ``ssm_heads``; the shared B/C streams stay replicated (G == 1 — the
    gate ``dist/tp.decode_ssm_tp`` requires it); ``w_out`` is row-parallel
    with an explicit psum (plus the RMS-statistic psum) in
    ``_gate_norm_out``.  Local dims are derived from the param shapes, so
    the same code runs replicated (tp_axis=None — bitwise the old path)."""
    Bsz = x.shape[0]
    N, G = cfg.ssm_state, cfg.ssm_groups
    P = cfg.ssm_head_dim
    di = p["w_x"].shape[1]          # local inner width (== cfg.d_inner unsharded)
    H = p["w_dt"].shape[1]          # local head count  (== cfg.ssm_heads unsharded)
    Hg = H // G

    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])

    xs, new_tail_x = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"],
                                  state.conv_x)
    bc, new_tail_bc = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                                   state.conv_bc)
    x_ssm = xs[:, 0].reshape(Bsz, G, Hg, P)
    Bm = bc[:, 0, :G * N].reshape(Bsz, G, N)
    Cm = bc[:, 0, G * N:].reshape(Bsz, G, N)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"][None]).reshape(Bsz, G, Hg)
    A = -jnp.exp(p["A_log"]).reshape(G, Hg)

    dA = jnp.exp(dtp * A[None])                            # [B,G,Hg]
    xdt = x_ssm.astype(jnp.float32) * dtp[..., None]
    h_new = state.h * dA[..., None, None] + \
        jnp.einsum("bgn,bghp->bghpn", Bm.astype(jnp.float32), xdt)
    y = jnp.einsum("bgn,bghpn->bghp", Cm.astype(jnp.float32), h_new)
    y = y + x_ssm.astype(jnp.float32) * p["D"].reshape(G, Hg)[None, ..., None]
    # match the prefill path's bf16 round-trip (ssd_chunked casts y to the
    # activation dtype) so decode == forward bitwise-closely
    y = y.astype(x.dtype).astype(jnp.float32)
    out = _gate_norm_out(p, y.reshape(Bsz, 1, di), z, x.dtype,
                         tp_axis=tp_axis, di_full=cfg.d_inner)
    return out, MambaState(h=h_new, conv_x=new_tail_x, conv_bc=new_tail_bc)


def init_mamba_state(cfg, batch: int, dtype) -> MambaState:
    G, Hg = cfg.ssm_groups, cfg.ssm_heads // cfg.ssm_groups
    return MambaState(
        h=jnp.zeros((batch, G, Hg, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
        conv_x=jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        conv_bc=jnp.zeros((batch, cfg.conv_width - 1,
                           2 * cfg.ssm_groups * cfg.ssm_state), dtype),
    )
