"""Minimal functional NN substrate: params are pytrees of jnp arrays; every
parameter carries *logical axis names* in a parallel pytree of
``jax.sharding.PartitionSpec``-ready tuples, which ``dist/sharding.py`` maps
to mesh axes (divisibility-aware).  No flax/haiku dependency — the framework
owns its module system (explicit init/apply pairs, scan-friendly stacked
layer parameters).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]  # same tree, leaves = tuple of logical axis names


@dataclasses.dataclass
class ParamAndAxes:
    params: Params
    axes: Axes


def _truncnorm(key, shape, scale, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool, dtype,
               axes: Tuple[str, str], scale: float | None = None):
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    p = {"w": _truncnorm(key, (d_in, d_out), scale, dtype)}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        a["b"] = (axes[1],)
    return p, a


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, dtype, axis_name: str = "embed"):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": (axis_name,)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype):
    # 1/sqrt(d) keeps tied-readout logits O(1) at init (rmsnorm'd features)
    p = {"embedding": _truncnorm(key, (vocab, d), d ** -0.5, dtype)}
    a = {"embedding": ("vocab", "embed")}
    return p, a


def embed_lookup(p, tokens):
    return p["embedding"][tokens]


def embed_logits(p, x):
    """Tied read-out: x [.., d] @ E^T -> [.., vocab]."""
    return x @ p["embedding"].T


def stack_layer_params(key, n: int, init_one: Callable[[jax.Array], Tuple[Params, Axes]]):
    """Initialize n copies of a layer and stack leaves along axis 0 (for
    lax.scan over layers).  Axes get a leading 'layer' (unsharded) name."""
    keys = jax.random.split(key, n)
    ps, axs = [], None
    for k in keys:
        p, a = init_one(k)
        ps.append(p)
        axs = a
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps)
    axes = jax.tree.map(lambda ax: ("layer",) + tuple(ax), axs,
                        is_leaf=lambda x: isinstance(x, tuple))
    return stacked, axes


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(params))
