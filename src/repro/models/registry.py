"""Family -> model module dispatch."""
from __future__ import annotations

from repro.models import encdec, hybrid, lm, ssm_lm

_FAMILY_MODULES = {
    "dense": lm,
    "moe": lm,
    "vlm": lm,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "encdec": encdec,
}


def get_model(cfg):
    return _FAMILY_MODULES[cfg.family]
