"""Decoder-only LM covering the dense / moe / vlm families (incl. gemma3's
5:1 local:global attention pattern), with scan-over-layers and logical
activation sharding constraints.

Layer stacking: uniform families scan over all L layers (one lowered layer
body).  gemma3 scans over L/6 *superblocks* of (5 local + 1 global) layers so
the sliding-window bound stays static inside ``flash_attention`` — local
layers cost O(S·window), global layers O(S²/2); the dry-run cost analysis
sees the true sub-quadratic FLOPs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import tp as TP
from repro.dist.ctx import shard_act
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import nn


def _layer_init(key, cfg, dtype):
    if cfg.family == "moe":
        k1, k2 = jax.random.split(key)
        pa, aa = L.attn_init(k1, cfg, dtype)
        pm, am = MOE.moe_init(k2, cfg, dtype)
        pn1, an1 = nn.norm_init(cfg.d_model, dtype)
        pn2, an2 = nn.norm_init(cfg.d_model, dtype)
        return ({"attn": pa, "moe": pm, "ln1": pn1, "ln2": pn2},
                {"attn": aa, "moe": am, "ln1": an1, "ln2": an2})
    return L.block_init(key, cfg, dtype)


def init(cfg, key) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    dtype = cfg.activation_dtype()
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    pe, ae = nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype)
    stacked, axes = nn.stack_layer_params(
        k_layers, cfg.num_layers,
        lambda k: _layer_init(k, cfg, dtype))
    pn, an = nn.norm_init(cfg.d_model, dtype)
    p = {"embed": pe, "layers": stacked, "final_norm": pn}
    a = {"embed": ae, "layers": axes, "final_norm": an}
    if not cfg.tie_embeddings:
        ph, ah = nn.dense_init(k_head, cfg.d_model, cfg.vocab_size,
                               bias=False, dtype=dtype,
                               axes=("embed", "vocab"))
        p["lm_head"] = ph
        a["lm_head"] = ah
    return p, a


def _apply_layer(cfg, p, x, positions, *, window: int,
                 mrope_positions=None):
    x = shard_act(x, ("batch", "seq", None))
    if cfg.family == "moe":
        x = TP.attn_apply_tp(cfg, p, x, positions, window=window,
                             mrope_positions=mrope_positions)
        y, aux = MOE.moe_apply(p["moe"], nn.rmsnorm(p["ln2"], x), cfg)
        return x + y, aux
    x = TP.block_apply_tp(cfg, p, x, positions, window=window,
                          mrope_positions=mrope_positions)
    return x, jnp.float32(0.0)


def _scan_layers(cfg, stacked, x, positions, mrope_positions,
                 remat: bool = False):
    """Scan over the layer stack; returns (x, total_aux)."""
    pat = cfg.pattern_local

    def body(carry, layer_p):
        x, aux = carry
        if pat:
            # superblock: pat local layers then 1 global
            for i in range(pat + 1):
                sub = jax.tree.map(lambda t: t[i], layer_p)
                win = cfg.local_window if i < pat else 0
                x, a = _apply_layer(cfg, sub, x, positions, window=win,
                                    mrope_positions=mrope_positions)
                aux = aux + a
        else:
            x, a = _apply_layer(cfg, layer_p, x, positions, window=0,
                                mrope_positions=mrope_positions)
            aux = aux + a
        return (x, aux), None

    if pat:
        group = pat + 1
        assert cfg.num_layers % group == 0, (cfg.num_layers, group)
        ng = cfg.num_layers // group
        stacked = jax.tree.map(
            lambda t: t.reshape((ng, group) + t.shape[1:]), stacked)

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    n_steps = cfg.num_layers // (pat + 1) if pat else cfg.num_layers
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)), stacked,
                               unroll=n_steps if cfg.unroll_layers else 1)
    return x, aux


def forward(cfg, params, tokens, *, positions=None, patch_embeds=None,
            mrope_positions=None, remat: bool = False,
            last_only: bool = False):
    """Full-sequence forward -> logits [B,S,V] (f32) and aux loss."""
    B, S = tokens.shape
    x = nn.embed_lookup(params["embed"], tokens)
    if patch_embeds is not None:
        # vision stub: patch embeddings occupy the first n_patch positions
        n_patch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype),
                             x[:, n_patch:]], axis=1)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    x = shard_act(x, ("batch", "seq", None))
    x, aux = _scan_layers(cfg, params["layers"], x, positions,
                          mrope_positions, remat=remat)
    if last_only:
        x = x[:, -1:]
    x = nn.rmsnorm(params["final_norm"], x)
    logits = _logits(cfg, params, x)
    return logits, aux


def _logits(cfg, params, x):
    if cfg.tie_embeddings:
        logits = nn.embed_logits(params["embed"], x)
    else:
        logits = nn.dense(params["lm_head"], x)
    return shard_act(logits.astype(jnp.float32), ("batch", "seq", "vocab"))


def loss_fn(cfg, params, tokens, labels, *, remat: bool = True):
    """Mean next-token cross entropy (labels = tokens shifted by caller)."""
    logits, aux = forward(cfg, params, tokens, remat=remat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux / cfg.num_layers
    return loss
