"""Shared model layers: RoPE / M-RoPE, GQA flash attention (chunked
online-softmax in pure JAX — the XLA-level flash formulation), SwiGLU MLP,
and the standard pre-norm transformer block.

Attention is O(S·window) / O(S²/2) in both memory and FLOPs: the query-chunk
scan's inner kv loop runs only over the chunks a query chunk can attend to
(causal triangle / sliding window), so the dry-run cost analysis reports the
true compute, not a dense S×S rectangle.  GQA is computed in grouped form
(q reshaped to [B,S,kv,group,hd]) — repeated KV is never materialized.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import nn

DEFAULT_Q_CHUNK = 512
DEFAULT_KV_CHUNK = 1024
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE.

def _rope_angles(positions, dims: int, theta: float):
    """positions [...] -> (sin, cos) [..., dims//2]."""
    half = dims // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float):
    """x [B,S,H,hd], positions [B,S] (or [S]) -> rotated x."""
    B, S, H, hd = x.shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, S))
    sin, cos = _rope_angles(positions, hd, theta)       # [B,S,hd/2]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...], theta: float):
    """Qwen2-VL M-RoPE: positions3 [3,B,S] (t,h,w); rotary dims split into
    ``sections`` (sum == hd//2); section s rotates with positions3[s]."""
    B, S, H, hd = x.shape
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # per-dim section id -> choose position stream
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)
    pos = positions3.astype(jnp.float32)                # [3,B,S]
    pos_per_dim = pos[sec_id, :, :]                     # [half,B,S]
    ang = jnp.einsum("dbs,d->bsd", pos_per_dim, freq)   # [B,S,half]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention (XLA-level chunked online softmax).

def _attend_chunk(q, k, v, qpos, kpos, kv_valid, *, causal, window, scale):
    """q [B,kv,G,Cq,hd]; k/v [B,kv,Ck,hd]; qpos [Cq]; kpos [Ck];
    kv_valid [Ck] (padding mask).
    Returns (scores-applied partial o [B,kv,G,Cq,hd], m, l)."""
    s = jnp.einsum("bkgqd,bkcd->bkgqc", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.broadcast_to(kv_valid[None, :],
                            (qpos.shape[0], kpos.shape[0]))
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                              # [B,kv,G,Cq]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, v.astype(jnp.float32))
    return o, m, l


def _grouped(q, k, v, Cq, Ck):
    """Reshape to chunked grouped layout.
    q -> [nq,B,kv,G,Cq,hd]; k/v -> [nk,B,kv,Ck,hd]."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    nq, nk = Sq // Cq, Sk // Ck
    qg = q.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kg = k.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 3, 2, 4)
    vg = v.reshape(B, nk, Ck, Hkv, hd).transpose(1, 0, 3, 2, 4)
    return qg, kg, vg, G, nq, nk


def _kv_bounds(qi, Cq, Ck, nk, q_offset, causal, window):
    """Traced [lo, hi) kv-chunk range a query chunk attends to."""
    if causal:
        hi = jnp.minimum((qi * Cq + Cq - 1 + q_offset) // Ck + 1, nk)
    else:
        hi = jnp.int32(nk)
    if window:
        lo = jnp.maximum((qi * Cq + q_offset - window + 1) // Ck, 0)
    else:
        lo = jnp.zeros((), jnp.int32)
    return lo, hi


def _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    scale = 1.0 / math.sqrt(hd)
    Cq = min(q_chunk, Sq)
    Ck = min(kv_chunk, Sk)
    nq = -(-Sq // Cq)
    nk = -(-Sk // Ck)
    q = _pad_to(q, 1, nq * Cq)
    k = _pad_to(k, 1, nk * Ck)
    v = _pad_to(v, 1, nk * Ck)
    qg, kg, vg, G, nq, nk = _grouped(q, k, v, Cq, Ck)
    valid_k = jnp.arange(nk * Ck) < Sk

    def one_q_chunk(args):
        qi, qc = args
        qpos = q_offset + qi * Cq + jnp.arange(Cq)
        lo, hi = _kv_bounds(qi, Cq, Ck, nk, q_offset, causal, window)

        def body(ki, st):
            o, m, l = st
            kc = jax.lax.dynamic_index_in_dim(kg, ki, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vg, ki, 0, keepdims=False)
            kpos = ki * Ck + jnp.arange(Ck)
            kv_valid = jax.lax.dynamic_slice_in_dim(valid_k, ki * Ck, Ck)
            oc, mc, lc = _attend_chunk(qc, kc, vc, qpos, kpos, kv_valid,
                                       causal=causal, window=window,
                                       scale=scale)
            m_new = jnp.maximum(m, mc)
            a = jnp.exp(m - m_new)
            b = jnp.exp(mc - m_new)
            return (o * a[..., None] + oc * b[..., None],
                    m_new, l * a + lc * b)

        o0 = jnp.zeros(qc.shape, jnp.float32)
        m0 = jnp.full(qc.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qc.shape[:-1], jnp.float32)
        o, m, l = jax.lax.fori_loop(lo, hi, body, (o0, m0, l0))
        lse = m + jnp.log(jnp.maximum(l, 1e-20))
        return o / jnp.maximum(l, 1e-20)[..., None], lse

    out, lse = jax.lax.map(one_q_chunk, (jnp.arange(nq), qg))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * Cq, Hq, hd)
    return out[:, :Sq].astype(q.dtype), lse     # lse [nq,B,kv,G,Cq]


def _flash_bwd_impl(res, dout, causal, window, q_chunk, kv_chunk, q_offset):
    """Flash backward: recompute scores per (q,kv) chunk pair; accumulate
    dk/dv in chunked f32 buffers via the q-chunk scan's carry.  No residual
    grows with S² anywhere."""
    q, k, v, out, lse = res
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    scale = 1.0 / math.sqrt(hd)
    Cq = min(q_chunk, Sq)
    Ck = min(kv_chunk, Sk)
    nq = -(-Sq // Cq)
    nk = -(-Sk // Ck)
    qp = _pad_to(q, 1, nq * Cq)
    kp = _pad_to(k, 1, nk * Ck)
    vp = _pad_to(v, 1, nk * Ck)
    dop = _pad_to(dout, 1, nq * Cq)
    op = _pad_to(out, 1, nq * Cq)
    qg, kg, vg, G, nq, nk = _grouped(qp, kp, vp, Cq, Ck)
    dog = dop.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)
    og = op.reshape(B, nq, Cq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)
    valid_k = jnp.arange(nk * Ck) < Sk
    # D_i = rowsum(do * o)  [nq,B,kv,G,Cq]
    Dg = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    def one_q_chunk(carry, args):
        dkg, dvg = carry                     # [nk,B,kv,Ck,hd] f32
        qi, qc, doc, Dc, lsec = args
        qpos = q_offset + qi * Cq + jnp.arange(Cq)
        lo, hi = _kv_bounds(qi, Cq, Ck, nk, q_offset, causal, window)
        doc32 = doc.astype(jnp.float32)

        def body(ki, st):
            dq, dkg, dvg = st
            kc = jax.lax.dynamic_index_in_dim(kg, ki, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vg, ki, 0, keepdims=False)
            kpos = ki * Ck + jnp.arange(Ck)
            kv_valid = jax.lax.dynamic_slice_in_dim(valid_k, ki * Ck, Ck)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(kv_valid[None, :], (Cq, Ck))
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lsec[..., None]), 0.0)
            dv_j = jnp.einsum("bkgqc,bkgqd->bkcd", p, doc32)
            dp = jnp.einsum("bkgqd,bkcd->bkgqc", doc32,
                            vc.astype(jnp.float32))
            ds = p * (dp - Dc[..., None]) * scale
            dq = dq + jnp.einsum("bkgqc,bkcd->bkgqd", ds,
                                 kc.astype(jnp.float32))
            dk_j = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qc.astype(jnp.float32))
            dkg = dkg.at[ki].add(dk_j)
            dvg = dvg.at[ki].add(dv_j)
            return dq, dkg, dvg

        dq0 = jnp.zeros(qc.shape, jnp.float32)
        dq, dkg, dvg = jax.lax.fori_loop(lo, hi, body, (dq0, dkg, dvg))
        return (dkg, dvg), dq

    dk0 = jnp.zeros((nk, B, Hkv, Ck, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, Hkv, Ck, hd), jnp.float32)
    (dkg, dvg), dqg = jax.lax.scan(
        one_q_chunk, (dk0, dv0),
        (jnp.arange(nq), qg, dog, Dg, lse))
    dq = dqg.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * Cq, Hq, hd)[:, :Sq]
    dk = dkg.transpose(1, 0, 3, 2, 4).reshape(B, nk * Ck, Hkv, hd)[:, :Sk]
    dv = dvg.transpose(1, 0, 3, 2, 4).reshape(B, nk * Ck, Hkv, hd)[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk,
                             q_offset)
    return out


def _flash_fwd_rule(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk,
                               q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, q_chunk, kv_chunk, q_offset, res, dout):
    return _flash_bwd_impl(res, dout, causal, window, q_chunk, kv_chunk,
                           q_offset)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_chunk: int = DEFAULT_Q_CHUNK,
                    kv_chunk: int = DEFAULT_KV_CHUNK,
                    q_offset: int = 0):
    """q [B,Sq,Hq,hd], k/v [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd].

    ``q_offset``: absolute position of q[0] (decode/chunked-prefill);
    kv positions are 0..Sk-1.  The kv loop visits only chunks within the
    causal triangle / sliding window of each query chunk, so FLOPs and
    memory are O(S·window) / O(S²/2), forward AND backward (custom VJP
    recomputes scores chunkwise — nothing S²-sized is ever saved)."""
    return _flash(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)


def _pad_to(x, axis, size):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Attention module (GQA, optional bias / padding to TP-friendly head counts).

def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_q, cfg.n_kv
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    p = {
        "wq": nn._truncnorm(ks[0], (d, nq, hd), scale, dtype),
        "wk": nn._truncnorm(ks[1], (d, nkv, hd), scale, dtype),
        "wv": nn._truncnorm(ks[2], (d, nkv, hd), scale, dtype),
        "wo": nn._truncnorm(ks[3], (nq, hd, d), scale, dtype),
    }
    a = {
        "wq": ("embed", "heads", "qk_head"),
        "wk": ("embed", "kv", "qk_head"),
        "wv": ("embed", "kv", "qk_head"),
        "wo": ("heads", "qk_head", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
        a["bq"] = ("heads", "qk_head")
        a["bk"] = ("kv", "qk_head")
        a["bv"] = ("kv", "qk_head")
    return p, a


def attn_qkv(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attn_qkv_decode(p, x):
    """Single-token QKV: x [B, d] -> q/k/v [B, H, hd].  Works on the full
    weights or on a TP head shard (the heads dim pre-sliced by shard_map —
    the decode-mode manual projection of dist/tp.py)."""
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attn_out_decode(p, o):
    """Single-token out projection: o [B, H, hd] -> [B, d].  On a TP head
    shard this is the row-parallel half — the caller psums over ``model``."""
    return jnp.einsum("bhk,hkd->bd", o, p["wo"])


def kv_head_slice(k, v, shard, kv_rep: int):
    """Per-chip KV head slice when KV heads are REPLICATED across a model
    axis wider than ``n_kv`` (kv_rep = tp / n_kv > 1): k/v [B, n_kv, hd]
    computed from replicated weights; chip ``shard`` keeps original head
    ``shard // kv_rep`` (exactly one head per chip — chips ``shard`` and
    ``shard ^ 1 ... `` holding the same head serve disjoint q-head groups,
    so nothing is double-counted downstream).  Identity when kv_rep == 1
    (the weights were already head-sharded by the enclosing shard_map)."""
    if kv_rep <= 1:
        return k, v
    head = shard // kv_rep
    return (jax.lax.dynamic_slice_in_dim(k, head, 1, axis=1),
            jax.lax.dynamic_slice_in_dim(v, head, 1, axis=1))


def self_attention(p, x, positions, cfg, *, window: int = 0,
                   mrope_positions=None, causal: bool = True):
    """Full-sequence self attention (train / prefill)."""
    q, k, v = attn_qkv(p, x)
    if mrope_positions is not None and cfg.mrope_sections:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, window=window)
    return attn_out(p, o)


def cross_attn_init(key, cfg, dtype):
    return attn_init(key, cfg, dtype)


def cross_attention(p, x, memory):
    """Encoder-decoder cross attention (no positions on k: memory carries
    its own encoding)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    o = flash_attention(q, k, v, causal=False)
    return attn_out(p, o)


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and block.

def mlp_init(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "wi_gate": nn._truncnorm(ks[0], (d, d_ff), s_in, dtype),
        "wi_up": nn._truncnorm(ks[1], (d, d_ff), s_in, dtype),
        "wo": nn._truncnorm(ks[2], (d_ff, d), s_out, dtype),
    }
    a = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
         "wo": ("mlp", "embed")}
    return p, a


def mlp_apply(p, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["wo"])


def block_init(key, cfg, dtype, d_ff: Optional[int] = None):
    """Standard pre-norm (attn + MLP) block."""
    k1, k2 = jax.random.split(key)
    pa, aa = attn_init(k1, cfg, dtype)
    pm, am = mlp_init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype)
    pn1, an1 = nn.norm_init(cfg.d_model, dtype)
    pn2, an2 = nn.norm_init(cfg.d_model, dtype)
    p = {"attn": pa, "mlp": pm, "ln1": pn1, "ln2": pn2}
    a = {"attn": aa, "mlp": am, "ln1": an1, "ln2": an2}
    return p, a


def block_apply(p, x, positions, cfg, *, window: int = 0,
                mrope_positions=None):
    h = self_attention(p["attn"], nn.rmsnorm(p["ln1"], x), positions, cfg,
                       window=window, mrope_positions=mrope_positions)
    x = x + h
    x = x + mlp_apply(p["mlp"], nn.rmsnorm(p["ln2"], x))
    return x
