"""The serving-facing routing layer over the sharded page table.

``ShardedPageTable`` is what the distributed serving stack holds instead of
a single ``PageTable`` + ``HashTable``: a set of ``dist.table_shard``
shards — one per host group (the pod axis of the production meshes) — and
the prefix manifest that routes every operation to its owner.

**Routing unit = the sequence.**  ``page_key = seq_id * MAX_LOGICAL_PAGES +
logical_page`` puts the sequence id in the key's top bits, so "shard by
hash prefix of seq_id" IS a key-space prefix partition — and it pins every
page of a sequence to one shard.  That choice is what lets the scheduler's
no-ABORT proof restate per shard: a lane's entire page demand lands on its
owner, so admission is gated by the owner shard's ``Headroom`` alone (see
``serving/sched/router.PrefixRouter``), never the global pool.

**Global slot space.**  Cell index = physical page holds per shard; the
facade lifts local cells into one global slot space by giving each table a
contiguous *region* ``[start, start+m)``.  A migrating shard temporarily
owns two regions (old + new); every migration step returns the physical
page moves as global (src, dst) pairs the pool owner applies incrementally
— the lazy counterpart of the eager ``PageTable.rehash`` permutation.  The
sim's global space only grows (retired old regions are not compacted; a
real deployment reuses them after ``finish``), which keeps every
outstanding block-table entry valid for its lifetime.

**Elasticity.**  ``lose_shard`` models a host group dying: its tables and
pages are simply gone.  The manifest hands the lost prefix ranges to the
survivors (``ShardManifest.reassign`` — survivors keep their own ranges,
so live sequences elsewhere are undisturbed) and the router re-admits the
lost lanes through the scheduler's recompute-preemption path
(``known_tokens`` replay).  ``dist.fault_tolerance.elastic_plan`` decides
the surviving mesh; ``plan_table_shards`` maps a mesh to its shard count
(one shard per pod-axis host group).

Everything here is host-driven eager jax between megasteps, like the
scheduler: the jitted decode megastep still sees one table per shard.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.dist import table_shard as TS
from repro.serving import page_table as PT


@dataclasses.dataclass(frozen=True)
class Region:
    """Global slot range backing one table: local cell i -> start + i."""
    start: int
    size: int

    def lift(self, local_slots: np.ndarray) -> np.ndarray:
        return np.where(local_slots >= 0, local_slots + self.start, -1)


@dataclasses.dataclass
class _ShardState:
    shard: TS.TableShard
    cur: Region                      # region of shard.table
    old: Optional[Region] = None     # region of shard.old while migrating


class ShardedPageTable:
    """Hash-prefix-sharded page table with per-shard headroom, lazy
    incremental resize, and elastic shard loss.  Mutable host object (like
    the scheduler); table pytrees live inside the shards."""

    def __init__(self, n_shards: int, pages_per_shard: int, *,
                 strategy: str = "linear",
                 prefix_bits: int = TS.DEFAULT_PREFIX_BITS,
                 page_size: int = 16, max_pages: int = 64, seed: int = 0):
        self.strategy = strategy
        self.page_size = page_size
        self.max_pages = max_pages
        self._pt = PT.for_strategy(strategy)
        self.manifest = TS.ShardManifest.balanced(n_shards, prefix_bits)
        self._shards: Dict[int, _ShardState] = {}
        self._next_start = 0
        for sid in range(n_shards):
            shard = TS.TableShard.create(sid, pages_per_shard,
                                         seed=seed + sid, strategy=strategy)
            self._shards[sid] = _ShardState(shard,
                                            self._claim(pages_per_shard))

    def _claim(self, size: int) -> Region:
        r = Region(self._next_start, size)
        self._next_start += size
        return r

    # -- topology --------------------------------------------------------

    @property
    def n_slots(self) -> int:
        """Extent of the global slot space (monotone — see module doc)."""
        return self._next_start

    def live_shards(self) -> Tuple[int, ...]:
        return tuple(sorted(self._shards))

    def shard(self, sid: int) -> TS.TableShard:
        return self._shards[sid].shard

    def owner_of_seq(self, seq_ids) -> np.ndarray:
        return self.manifest.owner_of_seq(seq_ids)

    # -- per-shard headroom (the admission controller's input) -----------

    def headroom(self, sid: int) -> PT.Headroom:
        """The owner shard's ``Headroom`` — same NamedTuple the scheduler
        already consumes, restated per shard.  During a migration
        ``free_cells = m_new - live_new - live_old`` (every un-migrated key
        has a new-table cell committed to it — ``TableShard.free_cells``),
        so ``demand + safety + slack <= free_cells`` remains a no-ABORT
        proof *through* the resize."""
        st = self._shards[sid]
        m = BT.size(st.shard.table)
        live = st.shard.live_pages()
        tombs = int(st.shard.table.num_tombs)
        if st.shard.old is not None:
            tombs += int(st.shard.old.num_tombs)
        return PT.Headroom(
            n_pages=m, live_pages=live, tombstones=tombs,
            free_cells=st.shard.free_cells(),
            live_fraction=live / max(m, 1),
            occupancy=(live + tombs) / max(m, 1),
            strategy=self.strategy,
            slack=self._pt.forecast_slack(m))

    # -- routed operations ------------------------------------------------

    def _route(self, seq_ids, active: np.ndarray
               ) -> List[Tuple[int, np.ndarray]]:
        """(shard_id, lane mask) per live shard with active lanes.  Lanes
        whose owner shard is dead (mid-recovery window) are dropped — the
        router re-admits them, so they must not reach a table."""
        owners = self.manifest.owner_of_seq(np.asarray(seq_ids))
        out = []
        for sid in self.live_shards():
            mask = (owners == sid) & active
            if mask.any():
                out.append((sid, mask))
        return out

    def _lift(self, st: _ShardState, slots, in_old) -> np.ndarray:
        """Local find result -> global slots via the owning region."""
        slots = np.asarray(slots)
        in_old = np.asarray(in_old)
        g = st.cur.lift(slots)
        if st.old is not None:
            g = np.where(in_old, st.old.lift(slots), g)
        return g

    def alloc_step(self, seq_ids, positions, *, active=None
                   ) -> Tuple[np.ndarray, np.ndarray, List[Tuple[int, int]]]:
        """Routed per-step allocation: each lane's page-boundary crossing
        inserts into its owner shard; every live lane's current page slot
        is read back.  Returns (global write_slot int32[B] — -1 refusal,
        aborted bool[B], page moves [(src_global, dst_global)] from
        migrate-on-access)."""
        seq_ids = np.asarray(seq_ids)
        positions = np.asarray(positions)
        B = positions.shape[0]
        act = (np.ones(B, bool) if active is None
               else np.asarray(active, bool))
        write_slot = np.full(B, -1, np.int32)
        aborted = np.zeros(B, bool)
        moves: List[Tuple[int, int]] = []
        page_idx = positions // self.page_size
        keys_all = np.asarray(PT.page_key(seq_ids, page_idx))
        need_new_all = ((positions % self.page_size) == 0) & act
        for sid, mask in self._route(seq_ids, act):
            st = self._shards[sid]
            keys = jnp.asarray(keys_all[mask])
            need = jnp.asarray(need_new_all[mask])
            shard, ret, mv = st.shard.insert(keys, active=need)
            moves += self._apply_moves(st, shard, mv)
            st.shard = shard
            ab = np.asarray(need & (ret == 2))
            found, slots, in_old = shard.find(keys)
            g = self._lift(st, slots, in_old)
            g = np.where(np.asarray(found) & ~ab, g, -1)
            write_slot[mask] = g.astype(np.int32)
            aborted[mask] = ab
            PT._note_probes(int(np.asarray(need).sum()) + int(mask.sum()))
        return write_slot, aborted, moves

    def free_sequences(self, seq_ids, positions, *, active=None
                       ) -> List[Tuple[int, int]]:
        """Routed eviction: delete every page key of each sequence on its
        owner shard (tombstone reuse applies per shard).  Returns any
        migrate-on-access page moves."""
        seq_ids = np.asarray(seq_ids)
        positions = np.asarray(positions)
        act = (np.ones(seq_ids.shape[0], bool) if active is None
               else np.asarray(active, bool))
        moves: List[Tuple[int, int]] = []
        logical = np.arange(self.max_pages, dtype=np.uint32)
        for sid, mask in self._route(seq_ids, act):
            st = self._shards[sid]
            keys = np.asarray(PT.page_key(seq_ids[mask, None],
                                          logical[None, :])).reshape(-1)
            need = (logical[None, :] <=
                    positions[mask, None] // self.page_size).reshape(-1)
            shard, _, mv = st.shard.delete(jnp.asarray(keys),
                                           active=jnp.asarray(need))
            moves += self._apply_moves(st, shard, mv)
            st.shard = shard
            PT._note_probes(int(need.sum()))
        return moves

    def lookup_pages(self, seq_ids, positions) -> np.ndarray:
        """Routed wait-free block-table read: global physical slot of every
        logical page of every sequence (-1 absent / dead-owner).
        int32[B, max_pages]."""
        seq_ids = np.asarray(seq_ids)
        positions = np.asarray(positions)
        B = seq_ids.shape[0]
        out = np.full((B, self.max_pages), -1, np.int32)
        logical = np.arange(self.max_pages, dtype=np.uint32)
        for sid, mask in self._route(seq_ids, np.ones(B, bool)):
            st = self._shards[sid]
            keys = np.asarray(PT.page_key(seq_ids[mask, None],
                                          logical[None, :])).reshape(-1)
            found, slots, in_old = st.shard.find(jnp.asarray(keys))
            g = self._lift(st, slots, in_old)
            g = np.where(np.asarray(found), g, -1)
            live = (logical[None, :] <=
                    positions[mask, None] // self.page_size)
            rows = g.reshape(-1, self.max_pages)
            out[mask] = np.where(live, rows, -1).astype(np.int32)
            PT._note_probes(int(mask.sum()) * self.max_pages)
        return out

    def insert_keys(self, keys) -> int:
        """Route raw page keys to their owners (checkpoint restore onto a
        different shard count re-homes every live key through this).
        Returns the number inserted."""
        keys = np.asarray(keys, np.uint32)
        seqs = keys // np.uint32(PT.MAX_LOGICAL_PAGES)
        n = 0
        for sid, mask in self._route(seqs, np.ones(keys.shape[0], bool)):
            st = self._shards[sid]
            shard, ret, mv = st.shard.insert(jnp.asarray(keys[mask]))
            self._apply_moves(st, shard, mv)
            st.shard = shard
            n += int(np.asarray(ret == 1).sum())
        return n

    # -- lazy incremental resize ------------------------------------------

    def grow_shard(self, sid: int, new_m: int) -> None:
        """Begin the lazy Section 4.3 grow of one shard: O(1) now, buckets
        migrate under traffic (on access + ``service_migration`` sweeps).
        The shard's headroom jumps to the new capacity immediately — the
        scheduler can admit against it before migration finishes."""
        st = self._shards[sid]
        st.shard = st.shard.begin_migration(new_m)
        st.old = st.cur
        st.cur = self._claim(new_m)

    def service_migration(self, chunk: int = TS.MIGRATE_CHUNK
                          ) -> List[Tuple[int, int]]:
        """One bounded migration round across all migrating shards (call
        once per serving round).  Returns global page moves to apply."""
        moves: List[Tuple[int, int]] = []
        for sid in self.live_shards():
            st = self._shards[sid]
            if not st.shard.migrating:
                continue
            shard, mv = st.shard.sweep_migrate(chunk)
            moves += self._apply_moves(st, shard, mv)
            st.shard = shard
        return moves

    def _apply_moves(self, st: _ShardState, shard: TS.TableShard,
                     mv: TS.MoveSet) -> List[Tuple[int, int]]:
        """Lift a MoveSet to global (src, dst) pairs; retire the old region
        when this step completed the migration."""
        out: List[Tuple[int, int]] = []
        if mv.n:
            assert st.old is not None
            src = st.old.lift(mv.old_slots)
            dst = st.cur.lift(mv.new_slots)
            out = list(zip(src.tolist(), dst.tolist()))
        if st.old is not None and not shard.migrating:
            st.old = None   # retired (not recycled — global space is
        return out          # monotone; see module doc)

    def migrating(self) -> Tuple[int, ...]:
        return tuple(sid for sid in self.live_shards()
                     if self._shards[sid].shard.migrating)

    # -- elasticity --------------------------------------------------------

    def lose_shard(self, sid: int) -> TS.ShardManifest:
        """A host group dies: its tables AND pages are gone.  Reassign its
        prefix ranges to the survivors and return the new manifest; the
        caller (``sched/router``) re-admits the lost sequences through
        recompute preemption."""
        if sid not in self._shards:
            raise KeyError(f"shard {sid} not live")
        del self._shards[sid]
        self.manifest = self.manifest.reassign(sid)
        return self.manifest

    # -- accounting --------------------------------------------------------

    def total_live_pages(self) -> int:
        return sum(st.shard.live_pages() for st in self._shards.values())

    def counters(self) -> Dict[int, Dict[str, int]]:
        """Per-shard counter snapshot for consistency checks."""
        out = {}
        for sid in self.live_shards():
            sh = self._shards[sid].shard
            mig, left = sh.migration_progress()
            out[sid] = {"live": sh.live_pages(),
                        "free": sh.free_cells(),
                        "n_cells": sh.n_cells(),
                        "migrated": mig, "migration_left": left}
        return out

    def health(self, sid: int) -> Dict[str, float]:
        """One shard's table-health gauge for the telemetry trace
        (``shard_health`` event, obs/trace.py): the paper's observable
        space-efficiency properties — tombstone density, probe-length p99
        (over current + frozen-old cells during a migration window) — plus
        the resize cursor's progress.  Eager/host-side; report-path only."""
        sh = self._shards[sid].shard
        mig, left = sh.migration_progress()
        n = sh.n_cells()
        tombs = int(sh.table.num_tombs)
        p99 = PT.PageTable.probe_p99(sh.table)
        if sh.old is not None:
            tombs += int(sh.old.num_tombs)
            p99 = max(p99, PT.PageTable.probe_p99(sh.old))
        live = sh.live_pages()
        return {"live": live, "tombs": tombs, "n_cells": n,
                "free": sh.free_cells(),
                "tomb_density": tombs / max(n, 1),
                "occupancy": (live + tombs) / max(n, 1),
                "probe_p99": p99,
                "migrated": mig, "migration_left": left}


# ---------------------------------------------------------------------------
# Sharded checkpointing (training/checkpoint.py format).  The table-layer
# payload per shard is its LIVE KEY SET: physical slots are not portable
# (the new job re-allocates pages and rebuilds block tables from the
# authoritative wait-free lookup, exactly as after a Section 4.3 rebuild),
# and the routing manifest rides in shards.json so restore can re-home
# every key onto a DIFFERENT shard count.


def checkpoint_sharded(spt: ShardedPageTable, ckpt_dir: str,
                       step: int) -> str:
    """Per-host shard writes + the manifest commit.  Returns the
    shards.json path (the commit point); safe to call again at the same
    step after the manifest changed (elastic remesh) — the re-commit
    replaces shards.json atomically."""
    from repro.training import checkpoint as CKPT
    for sid in spt.live_shards():
        sh = spt.shard(sid)
        keys, n = BT.live_keys(sh.table)
        live = [np.asarray(keys)[:int(n)]]
        if sh.old is not None:
            keys_o, n_o = BT.live_keys(sh.old)
            live.append(np.asarray(keys_o)[:int(n_o)])
        CKPT.save_shard(ckpt_dir, step, sid,
                        {"keys": np.concatenate(live).astype(np.uint32)},
                        extra={"strategy": spt.strategy,
                               "n_cells": sh.n_cells()})
    return CKPT.commit_sharded(
        ckpt_dir, step, shard_manifest=json.loads(spt.manifest.to_json()),
        extra={"page_size": spt.page_size, "max_pages": spt.max_pages})


def restore_sharded_table(ckpt_dir: str, n_shards: int,
                          pages_per_shard: int, *,
                          strategy: str = "linear",
                          step: Optional[int] = None,
                          page_size: Optional[int] = None,
                          max_pages: Optional[int] = None
                          ) -> Tuple[ShardedPageTable, int]:
    """Restore onto ``n_shards`` shards — any count, not just the saved
    one: every saved live key re-routes through the NEW balanced manifest
    (``insert_keys``), which is exactly the elastic-restore contract the
    mesh-agnostic format promises."""
    import json as _json

    from repro.training import checkpoint as CKPT
    shards, _saved_manifest, step = CKPT.restore_sharded(ckpt_dir, step=step)
    final = os.path.join(ckpt_dir, f"step_{step:08d}", "shards.json")
    with open(final) as f:
        extra = _json.load(f).get("extra", {})
    spt = ShardedPageTable(
        n_shards, pages_per_shard, strategy=strategy,
        page_size=int(page_size or extra.get("page_size", 16)),
        max_pages=int(max_pages or extra.get("max_pages", 64)))
    total = 0
    for payload in shards:
        total += spt.insert_keys(payload["keys"])
    n_keys = sum(int(p["keys"].size) for p in shards)
    if total != n_keys:
        raise RuntimeError(
            f"restore re-homed {total}/{n_keys} keys — target pool too "
            f"small or duplicate keys across shards")
    return spt, step


def plan_table_shards(mesh) -> int:
    """Shard count implied by a mesh: one table shard per pod-axis host
    group (the ``2x16x16`` production mesh runs 2), single-shard
    otherwise.  Recorded by dryrun cells as ``table_shards:``."""
    try:
        return int(mesh.shape.get("pod", 1))
    except AttributeError:
        return 1
