"""Deterministic synthetic workloads for the scheduler (bench / CI soak /
tests).  Everything is seeded and expressed in virtual-clock steps, so the
resulting scheduler statistics (aborts, preemptions, grows, completions)
are machine-independent and can be GATED in ``benchmarks/check_regression``.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.serving.sched.request import Request


def synthetic_workload(n: int, *, vocab_size: int, max_len: int,
                       seed: int = 0,
                       prompt_len=(2, 6),
                       max_new=(8, 24),
                       priorities: Sequence[int] = (0,),
                       slo_fraction: float = 0.0,
                       slo_budget=(24, 64),
                       arrival_every: int = 0) -> List[Request]:
    """``n`` requests with seeded random prompts.

    ``prompt_len`` / ``max_new`` / ``slo_budget`` are inclusive (lo, hi)
    ranges; ``priorities`` is cycled deterministically; ``slo_fraction`` of
    requests carry a ``max_latency`` SLO; ``arrival_every`` staggers
    arrivals by that many steps per request (0 = an admission storm: all
    arrive at step 0).  Total length is clamped to ``max_len``."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for i in range(n):
        lp = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        lp = min(lp, max_len - 1)
        new = int(rng.integers(max_new[0], max_new[1] + 1))
        new = max(1, min(new, max_len - lp))
        slo: Optional[int] = None
        if rng.random() < slo_fraction:
            slo = int(rng.integers(slo_budget[0], slo_budget[1] + 1))
        reqs.append(Request(
            req_id=i,
            prompt=rng.integers(0, vocab_size, size=lp).astype(np.int32),
            max_new_tokens=new,
            priority=int(priorities[i % len(priorities)]),
            max_latency=slo,
            arrival=i * int(arrival_every)))
    return reqs


def churn_request(req_id: int, rng: np.random.Generator, *,
                  vocab_size: int, max_len: int) -> Request:
    """One request of the classic eviction-churn stream the pre-scheduler
    batcher ran: a 1-token prompt with a stop uniform in
    [max_len // 3, max_len - 1].  The single source of truth — both
    ``churn_workload`` and the driver's endless auto-refill draw from it,
    so the distributions can never drift apart."""
    lo, hi = max_len // 3, max_len - 1
    return Request(req_id=req_id,
                   prompt=rng.integers(0, vocab_size, size=1).astype(
                       np.int32),
                   max_new_tokens=int(rng.integers(lo, hi)) - 1)


def churn_workload(n: int, *, vocab_size: int, max_len: int,
                   seed: int = 0) -> List[Request]:
    """``n`` churn requests (see ``churn_request``), all arriving
    immediately."""
    rng = np.random.default_rng(seed)
    return [churn_request(i, rng, vocab_size=vocab_size, max_len=max_len)
            for i in range(n)]
