"""Pluggable scheduling policies: admission order + preemption victims.

A policy answers two questions the scheduler asks every round:

* ``admit_order(queue)`` — in what order should arrived-but-queued requests
  be considered for free decode slots?
* ``preempt_candidates(running, queue)`` — which RUNNING requests may the
  headroom controller evict (recompute-preempt) when the occupancy
  forecaster predicts pool exhaustion?  Returned best-victim-first; an
  empty list means "never preempt for this policy — grow the pool instead".

The preemption rule is deliberately conservative: a victim must be
*dominated* by something still waiting (lower priority than a queued
request / later deadline than a queued deadline), so FCFS — where nothing
dominates anything — never preempts and relies purely on proactive growth.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.serving.sched.request import Request

_INF = float("inf")


def _deadline(r: Request) -> float:
    return _INF if r.deadline is None else float(r.deadline)


class Policy:
    """FCFS: arrival order, no preemption."""
    name = "fcfs"

    def admit_order(self, queue: Sequence[Request]) -> List[Request]:
        return sorted(queue, key=lambda r: (r.arrival, r.req_id))

    def preempt_candidates(self, running: Sequence[Request],
                           queue: Sequence[Request]) -> List[Request]:
        return []


class PriorityPolicy(Policy):
    """Strict priority (ties FCFS).  Victims: running requests whose
    priority is strictly below the best queued priority — lowest priority
    first, most recently admitted first (least sunk work recomputed)."""
    name = "priority"

    def admit_order(self, queue):
        return sorted(queue, key=lambda r: (-r.priority, r.arrival,
                                            r.req_id))

    def preempt_candidates(self, running, queue):
        if not queue:
            return []
        best_q = max(r.priority for r in queue)
        victims = [r for r in running if r.priority < best_q]
        return sorted(victims, key=lambda r: (r.priority,
                                              -(r.admitted_at or 0),
                                              -r.req_id))


class DeadlinePolicy(Policy):
    """SLO-aware EDF: earliest deadline first (requests without a
    ``max_latency`` sort last, then FCFS).  Victims: running requests whose
    deadline is strictly later than the most urgent queued deadline —
    slackest first (no-SLO lanes are the first to yield)."""
    name = "deadline"

    def admit_order(self, queue):
        return sorted(queue, key=lambda r: (_deadline(r), r.arrival,
                                            r.req_id))

    def preempt_candidates(self, running, queue):
        with_slo = [r for r in queue if r.deadline is not None]
        if not with_slo:
            return []
        urgent = min(_deadline(r) for r in with_slo)
        victims = [r for r in running if _deadline(r) > urgent]
        return sorted(victims, key=lambda r: (-_deadline(r),
                                              -(r.admitted_at or 0),
                                              -r.req_id))


POLICIES = {p.name: p for p in (Policy(), PriorityPolicy(),
                                DeadlinePolicy())}


def get_policy(name) -> Policy:
    if isinstance(name, Policy):
        return name
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r} "
                         f"(have: {sorted(POLICIES)})") from None
