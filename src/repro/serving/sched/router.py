"""Prefix router: the per-shard restatement of the admission proof.

``PrefixRouter`` fronts one ``Scheduler`` PER SHARD of a
``ShardedPageTable``.  Because sequences are pinned to their owner shard by
the hash prefix (``serving/sharded_table``), each scheduler sees exactly
the lanes whose pages land on its shard and gates admission with *that
shard's* ``Headroom`` — so the existing proactive invariant

    demand + safety + strategy_slack <= free_cells

holds per shard with the SAME forecaster, policies and preemption machinery
as the single-table scheduler; nothing in ``sched/scheduler.py`` changes.
The router adds exactly two things:

* **Placement** — a request gets its sequence id at submission (a plain
  counter); the id's hash prefix names the owner shard, and the request
  joins that shard's queue.  The seq id stays with the request for life —
  across preemptions and across host loss (the prefix RANGE moves to a
  survivor, so the same id routes to the new owner).

* **Elastic re-admission** (``lose_host``) — when a host group dies, its
  scheduler's running lanes and queue are re-homed: pages died with the
  host (nothing to free), so each running victim takes the scheduler's
  recompute-preemption transition (QUEUED, slot=None, ``known_tokens``
  carries its progress) and resubmits to the surviving owner named by the
  reassigned manifest.  Zero requests are lost by construction; the
  per-shard proof then guarantees the survivors re-admit them without
  ABORTs.

Pool growth is applied by the router, not the driver: a shard's ``grow_to``
triggers the LAZY resize (``grow_shard`` — O(1), headroom jumps
immediately, buckets migrate under traffic), so the proactive controller
no longer costs a stop-the-world rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sched.request import QUEUED, RUNNING, Request
from repro.serving.sched.scheduler import (Plan, Scheduler,
                                           latency_percentiles)
from repro.serving.sharded_table import ShardedPageTable


class PrefixRouter:
    """One scheduler per live shard; see module docstring."""

    def __init__(self, spt: ShardedPageTable, *, slots_per_shard: int,
                 max_len: int, megastep_k: int = 1, policy="fcfs",
                 proactive: bool = True, safety_pages: int = 0,
                 horizon_rounds: int = 2, allow_grow: bool = True,
                 max_pool_pages: Optional[int] = None, seq_base: int = 1):
        self.spt = spt
        self.slots_per_shard = int(slots_per_shard)
        self._sched_kw = dict(
            slots=slots_per_shard, page_size=spt.page_size, max_len=max_len,
            megastep_k=megastep_k, policy=policy, proactive=proactive,
            safety_pages=safety_pages, horizon_rounds=horizon_rounds,
            allow_grow=allow_grow, allow_preempt=True,
            max_pool_pages=max_pool_pages)
        self.scheds: Dict[int, Scheduler] = {}
        for sid in spt.live_shards():
            self.scheds[sid] = Scheduler(
                n_pages=spt.headroom(sid).n_pages, **self._sched_kw)
        self._next_seq = int(seq_base)
        self.seq_of: Dict[int, int] = {}      # req_id -> sequence id
        self.unique_submitted = 0             # per-shard counters double-
        self.rehomed = 0                      # count re-homes; these don't
        self.tracer = None                    # obs/trace.py span stream

    def set_tracer(self, tracer) -> None:
        """Install one Tracer across the router and every per-shard
        scheduler; each scheduler's spans carry its shard id as a tag."""
        self.tracer = tracer
        for sid, sc in self.scheds.items():
            sc.tracer = tracer
            sc.trace_tags = {"shard": sid}

    def _clock(self) -> int:
        return next(iter(self.scheds.values())).clock if self.scheds else 0

    # -- placement --------------------------------------------------------

    def owner_of(self, req: Request) -> int:
        seq = self.seq_of[req.req_id]
        return int(self.spt.owner_of_seq(np.asarray([seq], np.uint32))[0])

    def submit(self, req: Request) -> int:
        """Assign the request its (lifetime) sequence id, route it to the
        owner shard's scheduler.  Returns the owner shard id."""
        if req.req_id not in self.seq_of:
            self.seq_of[req.req_id] = self._next_seq
            self._next_seq += 1
            self.unique_submitted += 1
        owner = self.owner_of(req)
        self.scheds[owner].submit(req)
        return owner

    def submit_many(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.submit(r)

    # -- the round ---------------------------------------------------------

    def advance(self, steps: Optional[int] = None) -> None:
        for sc in self.scheds.values():
            sc.advance(steps)

    def plan_round(self, positions: Dict[int, Sequence[int]]
                   ) -> Dict[int, Plan]:
        """Per-shard planning against per-shard headroom.  ``positions``
        maps shard id -> post-megastep lane positions of that shard's
        scheduler.  A shard's ``grow_to`` is applied HERE as a lazy
        resize — by the time the plan reaches the driver the shard's
        headroom already covers it."""
        plans: Dict[int, Plan] = {}
        for sid, sc in self.scheds.items():
            old_pages = self.spt.headroom(sid).n_pages
            plan = sc.plan_round(positions[sid], self.spt.headroom(sid))
            if plan.grow_to is not None:
                self.spt.grow_shard(sid, plan.grow_to)
                if self.tracer is not None:
                    # the frozen-old-table window OPENS here; it closes at
                    # the migrate_done event the driver emits
                    self.tracer.emit("grow", sc.clock, shard=sid,
                                     n_pages_old=old_pages,
                                     n_pages_new=plan.grow_to)
            plans[sid] = plan
        return plans

    def end_round(self, keys_probed: int = 0) -> None:
        # attribute the driver-scoped probe count to the first shard (the
        # per-shard split isn't measured; totals still add up)
        for i, sc in enumerate(self.scheds.values()):
            sc.end_round(keys_probed if i == 0 else 0)

    # -- elasticity --------------------------------------------------------

    def lose_host(self, sid: int) -> List[Request]:
        """Host-group loss: reassign the shard's prefix ranges
        (``spt.lose_shard``) and re-home every request it held.  Running
        victims take the recompute-preemption transition — their pages died
        with the host, so there is nothing to free; ``known_tokens`` (the
        prompt + every token sampled so far) replays through chunked
        prefill on the new owner.  Returns the re-homed requests."""
        dead = self.scheds.pop(sid)
        self.spt.lose_shard(sid)
        victims = list(dead.running()) + list(dead.queue)
        if self.tracer is not None:
            self.tracer.emit("lose_host", dead.clock, shard=sid,
                             victims=[r.req_id for r in victims])
        for r in dead.running():
            r.state, r.slot = QUEUED, None
            r.preemptions += 1
        for r in victims:
            owner = self.owner_of(r)      # re-routes via the new manifest
            assert owner != sid
            self.scheds[owner].submit(r)
        self.rehomed += len(victims)
        return victims

    # -- aggregation -------------------------------------------------------

    @property
    def drained(self) -> bool:
        return all(sc.drained for sc in self.scheds.values())

    def finished(self) -> List[Request]:
        out: List[Request] = []
        for sc in self.scheds.values():
            out.extend(sc.finished)
        return out

    def summary(self) -> Dict[str, float]:
        """Cross-shard roll-up.  ``submitted`` counts unique requests (a
        re-home resubmits to another shard's counter; don't double-count);
        latency percentiles pool all finished requests."""
        total: Dict[str, float] = {}
        for sc in self.scheds.values():
            for k, v in dataclasses.asdict(sc.stats).items():
                total[k] = total.get(k, 0) + v
        total["submitted"] = self.unique_submitted
        total["rehomed"] = self.rehomed
        total.update(latency_percentiles(self.finished()))
        return total
