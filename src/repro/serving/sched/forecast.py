"""Occupancy forecaster: predict page-pool exhaustion BEFORE it happens.

The paper's amortized O(1) expected probe/step bound holds while the load
factor stays bounded; the allocator's ABORT (every cell live) is exactly
the regime where the guarantee — and the wait-free read path — degrades
into a Section 4.3 rebuild.  The forecaster keeps the table out of that
regime by construction:

* **Exact short-horizon demand.**  Page consumption at decode is fully
  determined by the lane positions: a lane at position ``p`` crosses a
  page boundary at every multiple of ``page_size`` in ``[p, p+steps)``.
  ``pages_needed`` counts those crossings exactly, so over one megastep
  (K steps, during which the host cannot intervene) "demand <= free_cells"
  is a *proof* of no-ABORT, not a heuristic — the controller enforces it
  before every dispatch (``Forecast.exhausted``).
* **Trend terms.**  EWMAs of the admit rate (requests/step) and the pool
  growth slope (net live pages/step, eviction churn included) extrapolate
  beyond the hard horizon: ``est_steps_to_exhaustion`` tells the
  controller how soon the pool runs out at the current churn, which gates
  admissions earlier than the hard one-round bound would.

``free_cells`` counts tombstones as free — tombstone reuse (Prop. 2 as the
allocator) means a freed slot is immediately re-claimable and an ABORT can
only happen when every cell holds a *live* key.

The no-ABORT proof per probe strategy (``core/probe_strategies.py``):

* **linear** — Prop. 2 verbatim: an insert ABORTs iff every cell holds a
  live key, so ``demand <= free_cells`` (free = empty + tombstones) is
  exact.  ``strategy_slack = 0``.
* **robinhood** — identical claim reachability: displacement only reorders
  WHICH lane wins a cell, never whether a free cell is claimable (the
  probe sequence and the available-cell predicate are unchanged), so
  Prop. 2 carries over unchanged.  ``strategy_slack = 0``.
* **hopscotch** — ``free_cells`` is exact (no tombstones: deletes free the
  cell immediately), but an insert needs a free cell *within H of its
  home* and displacement can fail to create one below full load.  The
  strategy therefore reports ``forecast_slack = H`` (0 when the pool fits
  inside one neighborhood, where near-claim sees every free cell and the
  bound is again exact): the controller must keep
  ``demand + safety + slack <= free_cells``.  The slack makes the bound
  conservative, not exact — the reactive rebuild path stays live as the
  backstop for the (rare) displacement-stuck ABORT inside the slack.

The slack is threaded as data, not strategy names: the engine's
``Headroom.slack`` (filled by ``page_table.PageTable.forecast_slack``)
reaches ``Forecast.strategy_slack`` via ``Scheduler.plan_round``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def pages_held(pos: int, page_size: int) -> int:
    """Pages a lane owns after processing positions [0, pos)."""
    return -(-int(pos) // page_size)


def pages_needed(pos: int, steps: int, page_size: int) -> int:
    """EXACT page demand of one lane processing positions
    [pos, pos + steps): the number of page-boundary crossings
    (multiples of ``page_size``) in that half-open range."""
    if steps <= 0:
        return 0
    a, b = int(pos), int(pos) + int(steps)
    return -(-b // page_size) - (-(-a // page_size))


@dataclasses.dataclass(frozen=True)
class Forecast:
    """One round's occupancy forecast (all page counts are exact for the
    hard horizon; the *_ewma / est_* fields are trend extrapolations)."""
    horizon_steps: int
    demand_pages: int            # exact demand over the hard horizon
    free_cells: int              # n_pages - live (tombstones reusable)
    safety_pages: int
    admit_rate_ewma: float       # requests / step
    growth_slope_ewma: float     # net live pages / step (churn included)
    est_steps_to_exhaustion: float
    strategy_slack: int = 0      # probe-strategy headroom (see module doc)

    @property
    def margin(self) -> int:
        return (self.free_cells - self.demand_pages - self.safety_pages
                - self.strategy_slack)

    @property
    def exhausted(self) -> bool:
        """True when the next ``horizon_steps`` provably cannot be served
        without an ABORT unless the controller evicts or grows first."""
        return self.margin < 0


class OccupancyForecaster:
    """Stateful forecaster: exact short-horizon demand + EWMA trends.

    ``observe`` once per round with that round's measurements; ``forecast``
    whenever a decision needs the current picture (admission gating, the
    headroom check before dispatch)."""

    def __init__(self, page_size: int, *, safety_pages: int = 0,
                 ewma: float = 0.5):
        self.page_size = page_size
        self.safety_pages = int(safety_pages)
        self.ewma = float(ewma)
        self.admit_rate = 0.0
        self.growth_slope = 0.0
        self._last_live: Optional[int] = None

    # -- measurement ------------------------------------------------------

    def observe(self, *, admitted: int, live_pages: int, steps: int) -> None:
        """Fold one round's measurements into the trend EWMAs.  ``steps``
        is the round length (K); ``live_pages`` the post-round live count
        (net of eviction churn)."""
        steps = max(int(steps), 1)
        a = self.ewma
        self.admit_rate = a * (admitted / steps) + (1 - a) * self.admit_rate
        if self._last_live is not None:
            slope = (live_pages - self._last_live) / steps
            self.growth_slope = a * slope + (1 - a) * self.growth_slope
        self._last_live = int(live_pages)

    # -- prediction -------------------------------------------------------

    def demand(self, positions: Sequence[int], stops: Sequence[int],
               horizon_steps: int) -> int:
        """Exact aggregate page demand of the given lanes over the next
        ``horizon_steps``: each lane runs ``min(horizon, stop - pos)``
        more steps and allocates one page per boundary crossed."""
        total = 0
        for p, s in zip(positions, stops):
            total += pages_needed(p, min(int(horizon_steps),
                                         max(int(s) - int(p), 0)),
                                  self.page_size)
        return total

    def forecast(self, positions: Sequence[int], stops: Sequence[int],
                 free_cells: int, horizon_steps: int,
                 strategy_slack: int = 0) -> Forecast:
        d = self.demand(positions, stops, horizon_steps)
        # trend extrapolation: NET live-page slope (eviction churn cancels
        # out, so steady-state churn extrapolates to "never") plus the
        # admit-rate term (each admission claims its first page
        # immediately).  Consumed by the scheduler's admission gate: an
        # est_steps_to_exhaustion inside the lookahead defers admissions
        # earlier than the exact-demand bound alone would.
        slack = int(strategy_slack)
        rate = max(self.growth_slope, 0.0) + max(self.admit_rate, 0.0)
        est = (float("inf") if rate <= 0.0
               else max(free_cells - self.safety_pages - slack, 0) / rate)
        return Forecast(horizon_steps=int(horizon_steps), demand_pages=d,
                        free_cells=int(free_cells),
                        safety_pages=self.safety_pages,
                        admit_rate_ewma=self.admit_rate,
                        growth_slope_ewma=self.growth_slope,
                        est_steps_to_exhaustion=est,
                        strategy_slack=slack)
