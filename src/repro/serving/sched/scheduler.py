"""SLO-aware continuous-batching scheduler with proactive admission control.

The scheduler owns every admit / evict / preempt / grow decision; the
driver (``launch/serve.py`` ``ContinuousBatcher``) owns the engine state
and the megastep dispatch.  One round = one K-token megastep:

    driver: dispatch megastep -> sync pos/aborts -> absorb sampled tokens
    sched:  advance(K) -> plan_round(positions, pool) -> Plan
    driver: apply Plan (free_sequences / invalidate rows / rebuild-grow /
            admit fresh seq ids) -> end_round(keys_probed)

``plan_round`` runs four phases:

1. **Completion** — lanes whose position reached their stop finish; their
   slots free and their pages are counted as reclaimable this round.
2. **Admission** (policy-ordered, forecaster-gated) — queued requests whose
   predicted page demand over the lookahead horizon fits the predicted
   headroom are admitted into free slots; chunked prefill starts at the
   next megastep via the engine's teacher-forcing path.  With
   ``proactive=False`` admission is greedy (the reactive baseline).
3. **Headroom control** (proactive only) — the hard invariant: exact page
   demand of the occupied lanes over the NEXT megastep (during which the
   host cannot intervene) must fit ``free_cells`` minus the probe
   strategy's slack (``Headroom.slack`` — 0 for linear/robinhood where the
   bound is exact, H for hopscotch; see ``sched/forecast.py``).  If not, preempt
   policy-dominated victims (recompute preemption: pages freed, request
   re-queued with its generated tokens folded into the prompt) and/or
   grow the pool (Section 4.3 rebuild into 2x cells) — BEFORE dispatch, so
   the allocator never ABORTs and the wait-free lookup path never sees a
   mid-flight rebuild.  Every round where this fires and resolves is an
   ``aborts_avoided`` tick.
4. **Accounting** — the forecaster EWMAs observe the round; per-round
   ``RoundStats`` (including the scoped ``PROBE_STATS`` key count the
   driver measures) append to ``rounds``.

All timing is virtual (decode steps), so stats are deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sched.forecast import (Forecast, OccupancyForecaster,
                                          pages_held, pages_needed)
from repro.serving.sched.policy import Policy, get_policy
from repro.serving.sched.request import DONE, QUEUED, RUNNING, Request


@dataclasses.dataclass
class Plan:
    """One round's decisions, for the driver to apply to the engine state.
    ``evict_slots`` = finished + preempted (free pages, invalidate rows,
    deactivate); ``admissions`` = (slot, request) to seat with a fresh
    sequence id at position 0; ``grow_to`` = proactive pool growth target
    (cells), applied via ``engine.rebuild_page_table`` BEFORE the next
    dispatch."""
    finish_slots: List[int]
    preempt_slots: List[int]
    admissions: List[Tuple[int, Request]]
    grow_to: Optional[int]
    forecast: Optional[Forecast]

    @property
    def evict_slots(self) -> List[int]:
        return sorted(set(self.finish_slots) | set(self.preempt_slots))


@dataclasses.dataclass
class RoundStats:
    round_idx: int
    clock: int
    admitted: int
    completed: int
    preempted: int
    aborts: int
    grew_to: Optional[int]
    queue_len: int
    active_lanes: int
    free_cells: Optional[int]
    demand_pages: Optional[int]
    live_fraction: Optional[float]
    keys_probed: int = 0


@dataclasses.dataclass
class SchedStats:
    submitted: int = 0
    admitted: int = 0            # admission events (re-admissions count)
    completed: int = 0
    preemptive_evictions: int = 0
    aborts: int = 0              # lane-rounds that hit the reactive ABORT
    aborts_avoided: int = 0      # rounds where proactive action prevented one
    pool_grows: int = 0          # proactive grows
    reactive_rebuilds: int = 0   # post-abort rebuilds (the old path)
    deadline_misses: int = 0
    forecast_unresolved: int = 0 # predicted exhaustion nothing could fix


class Scheduler:
    """See module docstring.  ``slots`` = decode lanes (B); ``n_pages`` =
    the (possibly overcommitted) pool size the driver allocated;
    ``max_len`` = engine S_max (stops are clamped to it)."""

    def __init__(self, *, slots: int, page_size: int, max_len: int,
                 n_pages: Optional[int] = None, megastep_k: int = 1,
                 policy="fcfs", proactive: bool = True,
                 horizon_rounds: int = 2, safety_pages: int = 0,
                 allow_grow: bool = True, allow_preempt: bool = True,
                 max_pool_pages: Optional[int] = None,
                 max_prefill_lanes: Optional[int] = None,
                 ewma: float = 0.5):
        self.B = int(slots)
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.n_pages = None if n_pages is None else int(n_pages)
        self.K = max(1, int(megastep_k))
        self.policy: Policy = get_policy(policy)
        self.proactive = bool(proactive)
        self.horizon_rounds = max(1, int(horizon_rounds))
        self.allow_grow = bool(allow_grow)
        self.allow_preempt = bool(allow_preempt)
        self.max_pool_pages = max_pool_pages
        self.max_prefill_lanes = max_prefill_lanes
        self.forecaster = OccupancyForecaster(page_size,
                                              safety_pages=safety_pages,
                                              ewma=ewma)
        self.clock = 0
        self.queue: List[Request] = []
        self.lanes: List[Optional[Request]] = [None] * self.B
        self.finished: List[Request] = []
        self.stats = SchedStats()
        self.rounds: List[RoundStats] = []
        self._pending: Optional[RoundStats] = None
        self._abort_accum = 0
        # span tracing (obs/trace.py): a driver/router installs a Tracer
        # and tag dict (e.g. {"shard": sid}) after construction; every
        # lifecycle transition below then emits its span edge on the
        # virtual clock.  None = zero overhead.
        self.tracer = None
        self.trace_tags: Dict[str, int] = {}

    def _emit(self, event: str, **fields) -> None:
        if self.tracer is not None:
            self.tracer.emit(event, self.clock, **self.trace_tags,
                             **fields)

    # -- intake -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.arrival = max(int(req.arrival), self.clock)
        req.state = QUEUED
        self.queue.append(req)
        self.stats.submitted += 1
        self._emit("arrival", req=req.req_id, resubmit=req.preemptions)

    def submit_many(self, reqs: Sequence[Request]) -> None:
        for r in reqs:
            self.submit(r)

    # -- introspection ----------------------------------------------------

    def stop_of(self, req: Request) -> int:
        return min(req.total_len, self.max_len)

    def running(self) -> List[Request]:
        return [r for r in self.lanes if r is not None]

    def arrived_queue(self) -> List[Request]:
        return [r for r in self.queue if r.arrival <= self.clock]

    @property
    def drained(self) -> bool:
        return not self.queue and all(r is None for r in self.lanes)

    # -- lifecycle transitions (idempotent) -------------------------------

    def _finish(self, req: Request) -> bool:
        if req.state != RUNNING:
            return False                      # idempotent double-evict
        if req.slot is not None:
            self.lanes[req.slot] = None
        req.state, req.slot = DONE, None
        req.finished_at = self.clock
        self.finished.append(req)
        self.stats.completed += 1
        if req.missed_deadline:
            self.stats.deadline_misses += 1
        self._emit("finish", req=req.req_id, tokens=len(req.sampled),
                   ttft=req.ttft(), tpot=req.tpot())
        return True

    def _preempt(self, req: Request) -> bool:
        if req.state != RUNNING:
            return False                      # idempotent double-evict
        if req.slot is not None:
            self.lanes[req.slot] = None
        req.state, req.slot = QUEUED, None
        req.preemptions += 1
        self.queue.append(req)
        self.stats.preemptive_evictions += 1
        self._emit("preempt", req=req.req_id)
        return True

    def evict(self, req: Request) -> bool:
        """Forcibly evict a RUNNING request back to the queue (recompute
        preemption).  Calling it again — or on a finished/queued request —
        is a no-op returning False: double-evict is idempotent by
        construction (the driver frees a slot's pages at most once because
        the slot empties on the first call)."""
        return self._preempt(req)

    def _admit(self, req: Request, slot: int) -> None:
        self.queue.remove(req)
        req.state, req.slot = RUNNING, slot
        if req.admitted_at is None:           # queue-wait = FIRST admission
            req.admitted_at = self.clock
        req._prefill_len = int(req.known_tokens().size)  # noqa: SLF001
        self.lanes[slot] = req
        self.stats.admitted += 1
        self._emit("admit", req=req.req_id, slot=slot,
                   prefill=req._prefill_len,  # noqa: SLF001
                   readmit=req.preemptions)

    # -- the round --------------------------------------------------------

    def advance(self, steps: Optional[int] = None) -> None:
        """Advance the virtual clock by one megastep (called by the driver
        right after the dispatch returns)."""
        self.clock += self.K if steps is None else int(steps)

    def note_aborts(self, n_lanes: int, grew_to: Optional[int] = None) -> None:
        """Reactive path: the dispatch surfaced ``n_lanes`` ABORTed lanes
        (the forecaster was off, capped, or wrong) and the driver rebuilt."""
        self.stats.aborts += int(n_lanes)
        self._abort_accum += int(n_lanes)
        self._emit("abort", lanes=int(n_lanes), grew_to=grew_to)
        if grew_to is not None:
            self.stats.reactive_rebuilds += 1
            self.n_pages = int(grew_to)

    def plan_round(self, positions: Sequence[int],
                   pool=None) -> Plan:
        """Decide this round's actions.  ``positions`` int[B] are the
        post-megastep lane positions; ``pool`` is the engine's
        ``page_table.Headroom`` (None for attention-free families —
        admission is then slot-gated only)."""
        pos = np.asarray(positions, np.int64)
        K, ps = self.K, self.page_size
        # probe-strategy headroom: hopscotch reports slack = H because an
        # insert needs a free cell within its neighborhood (see
        # sched/forecast.py module doc); linear/robinhood report 0 and the
        # bound stays exact.  Threaded as data from Headroom, never by name.
        slack = 0 if pool is None else int(getattr(pool, "slack", 0))

        # 1. completions -------------------------------------------------
        finish_slots: List[int] = []
        reclaimed = 0
        for s in range(self.B):
            r = self.lanes[s]
            if r is not None and pos[s] >= self.stop_of(r):
                self._finish(r)
                finish_slots.append(s)
                reclaimed += pages_held(pos[s], ps)
        free_cells = None
        if pool is not None:
            # pool was measured before the driver frees the finished lanes
            free_cells = pool.free_cells + reclaimed

        # planned (pos, stop) of lanes that keep running
        lane_view: Dict[int, Tuple[int, int]] = {
            s: (int(pos[s]), self.stop_of(r))
            for s, r in enumerate(self.lanes) if r is not None}

        # 2. admission (policy-ordered, forecaster-gated) -----------------
        free_slots = [s for s in range(self.B) if self.lanes[s] is None]
        admissions: List[Tuple[int, Request]] = []
        horizon = self.horizon_rounds * K
        margin = None
        if free_cells is not None:
            demand_running = self.forecaster.demand(
                [p for p, _ in lane_view.values()],
                [st for _, st in lane_view.values()], horizon)
            margin = (free_cells - demand_running
                      - self.forecaster.safety_pages - slack)
        prefilling = sum(
            1 for s, r in enumerate(self.lanes) if r is not None
            and pos[s] < getattr(r, "_prefill_len", 0))
        # trend gate: when the EWMA slope + admit-rate extrapolation says
        # the pool exhausts within the lookahead, stop admitting NOW —
        # earlier than the exact-demand margin alone would
        trend_defer = False
        if self.proactive and free_cells is not None:
            tr = self.forecaster.forecast(
                [p for p, _ in lane_view.values()],
                [st for _, st in lane_view.values()], free_cells, horizon,
                strategy_slack=slack)
            trend_defer = tr.est_steps_to_exhaustion < horizon
        for r in self.policy.admit_order(self.arrived_queue()):
            if not free_slots or trend_defer:
                break
            if (self.max_prefill_lanes is not None
                    and prefilling >= self.max_prefill_lanes):
                break
            need = 0
            if free_cells is not None:
                need = pages_needed(0, min(horizon, self.stop_of(r)), ps)
            if self.proactive and margin is not None and need > margin:
                break            # would overrun predicted capacity — wait
            slot = free_slots.pop(0)
            self._admit(r, slot)
            admissions.append((slot, r))
            lane_view[slot] = (0, self.stop_of(r))
            prefilling += 1
            if margin is not None:
                margin -= need

        # 3. proactive headroom control (the hard one-megastep invariant) -
        preempt_slots: List[int] = []
        grow_to: Optional[int] = None
        fc: Optional[Forecast] = None
        if free_cells is not None:
            fc = self.forecaster.forecast(
                [p for p, _ in lane_view.values()],
                [st for _, st in lane_view.values()], free_cells, K,
                strategy_slack=slack)
            if self.proactive and fc.exhausted:
                needed = -fc.margin
                admitted_set = {id(r) for _, r in admissions}
                if self.allow_preempt:
                    cands = self.policy.preempt_candidates(
                        [r for r in self.running()
                         if id(r) not in admitted_set],
                        self.arrived_queue())
                    for v in cands:
                        if needed <= 0:
                            break
                        s = v.slot
                        p, st = lane_view.pop(s)
                        self._preempt(v)
                        preempt_slots.append(s)
                        needed -= (pages_held(p, ps)
                                   + pages_needed(p, min(K, st - p), ps))
                if needed > 0 and self.allow_grow:
                    # double until the deficit is covered; max_pool_pages
                    # bounds the RESULT (the last step clamps to the cap —
                    # partial growth still helps; pick a cap that respects
                    # the mesh's page-shard divisibility)
                    new_pages = self.n_pages or 0
                    gained = 0
                    while needed - gained > 0 and new_pages > 0:
                        nxt = new_pages * 2
                        if self.max_pool_pages is not None:
                            nxt = min(nxt, int(self.max_pool_pages))
                        if nxt <= new_pages:
                            break                        # cap reached
                        new_pages = nxt
                        gained = new_pages - self.n_pages
                    if new_pages > (self.n_pages or 0):
                        grow_to = new_pages
                        needed -= gained
                if needed <= 0:
                    self.stats.aborts_avoided += 1
                    if grow_to is not None:
                        self.stats.pool_grows += 1
                        self.n_pages = grow_to
                else:
                    self.stats.forecast_unresolved += 1

        # 4. accounting ---------------------------------------------------
        live_now = (pool.live_pages - reclaimed) if pool is not None else 0
        self.forecaster.observe(admitted=len(admissions),
                                live_pages=live_now, steps=K)
        self._pending = RoundStats(
            round_idx=len(self.rounds), clock=self.clock,
            admitted=len(admissions), completed=len(finish_slots),
            preempted=len(preempt_slots), aborts=self._abort_accum,
            grew_to=grow_to,
            queue_len=len(self.queue),
            active_lanes=sum(r is not None for r in self.lanes),
            free_cells=free_cells,
            demand_pages=None if fc is None else fc.demand_pages,
            live_fraction=None if pool is None else pool.live_fraction)
        self._abort_accum = 0
        return Plan(finish_slots=finish_slots, preempt_slots=preempt_slots,
                    admissions=admissions, grow_to=grow_to, forecast=fc)

    def end_round(self, keys_probed: int = 0) -> RoundStats:
        """Finalize the round's stats (the driver passes the scoped
        ``PROBE_STATS`` count it measured across dispatch + plan apply)."""
        rs = self._pending
        if rs is None:
            raise RuntimeError("end_round without a plan_round")
        rs.keys_probed = int(keys_probed)
        self.rounds.append(rs)
        self._pending = None
        return rs

    # -- summaries --------------------------------------------------------

    def latency_summary(self) -> Dict[str, float]:
        """Deterministic virtual-clock latency percentiles over finished
        requests (steps): queue-wait (arrival -> first admission), TTFT
        (arrival -> first sampled token) and TPOT (steps per output token
        after the first, preemption stalls included)."""
        return latency_percentiles(self.finished)

    def summary(self) -> Dict[str, float]:
        s = dataclasses.asdict(self.stats)
        s.update(self.latency_summary())
        return s


def latency_percentiles(finished: Sequence[Request]) -> Dict[str, float]:
    """queue_wait / ttft / tpot p50+p99 over finished requests — shared by
    ``Scheduler.latency_summary`` and the router's cross-shard roll-up."""
    out: Dict[str, float] = {}
    series = (("queue_wait", [r.queue_wait() for r in finished]),
              ("ttft", [r.ttft() for r in finished]),
              ("tpot", [r.tpot() for r in finished]))
    for name, xs in series:
        xs = [x for x in xs if x is not None]
        if xs:
            out[f"{name}_p50"] = float(np.percentile(xs, 50))
            out[f"{name}_p99"] = float(np.percentile(xs, 99))
        else:
            out[f"{name}_p50"] = out[f"{name}_p99"] = float("nan")
    return out
