"""``repro.serving.sched`` — proactive admission control & SLO-aware
continuous-batching scheduler over the hash-table page allocator.

See README.md in this directory for the design: request lifecycle
(``request``), occupancy forecaster (``forecast``), pluggable policies
(``policy``), and the scheduler + proactive headroom controller
(``scheduler``).  ``workload`` builds deterministic synthetic traffic for
bench / CI soak.  ``router`` stacks one scheduler per table shard behind
hash-prefix routing (``serving/sharded_table``) so the proactive no-ABORT
proof restates per shard.
"""
from repro.serving.sched.forecast import (Forecast, OccupancyForecaster,
                                          pages_held, pages_needed)
from repro.serving.sched.policy import (DeadlinePolicy, POLICIES, Policy,
                                        PriorityPolicy, get_policy)
from repro.serving.sched.request import (DONE, QUEUED, RUNNING, Request)
from repro.serving.sched.router import PrefixRouter
from repro.serving.sched.scheduler import (Plan, RoundStats, SchedStats,
                                           Scheduler)
from repro.serving.sched.workload import (churn_request, churn_workload,
                                          synthetic_workload)

__all__ = [
    "DONE", "QUEUED", "RUNNING", "Request",
    "Forecast", "OccupancyForecaster", "pages_held", "pages_needed",
    "Policy", "PriorityPolicy", "DeadlinePolicy", "POLICIES", "get_policy",
    "Plan", "RoundStats", "SchedStats", "Scheduler", "PrefixRouter",
    "churn_request", "churn_workload", "synthetic_workload",
]
