"""Request lifecycle for the serving scheduler.

A ``Request`` is the unit of admission: it arrives (virtual-clock step
``arrival``), waits in the queue, is ADMITTED into a decode slot (its prompt
is chunked-prefilled through the megastep's teacher-forcing path —
``engine.make_serve_megastep`` ``forced``/``forced_mask``), DECODEs greedy
tokens, and finishes (slot evicted, pages tombstoned and reclaimed).  A
running request can be PREEMPTED by the headroom controller: its pages are
freed, its generated-so-far tokens fold into ``known_tokens`` and it
re-queues — on re-admission the whole history is recomputed via chunked
prefill (vLLM-style recompute preemption; the model is deterministic, so
the continuation is unaffected).

All timing is in VIRTUAL-CLOCK decode steps (the scheduler advances the
clock by K per megastep round), so queue-wait / TTFT / latency accounting
is machine-independent and deterministic — the SLO field ``max_latency``
is a step budget from arrival.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

QUEUED = "queued"       # waiting for admission (incl. after a preemption)
RUNNING = "running"     # owns a decode slot (prefill or decode phase)
DONE = "done"


@dataclasses.dataclass
class Request:
    """One serving request.  ``prompt`` must hold at least one token (the
    first feed).  ``max_new_tokens`` counts the sampled tokens after the
    prompt; the target total length is clamped to the engine's ``S_max`` by
    the scheduler at admission."""
    req_id: int
    prompt: np.ndarray                       # int32 [Lp >= 1]
    max_new_tokens: int
    priority: int = 0                        # higher = more important
    max_latency: Optional[int] = None        # SLO: steps from arrival
    arrival: int = 0                         # virtual-clock arrival step

    # -- lifecycle (scheduler-owned) --------------------------------------
    state: str = QUEUED
    slot: Optional[int] = None
    admitted_at: Optional[int] = None        # first admission
    first_token_at: Optional[int] = None     # first sampled (non-forced) tok
    finished_at: Optional[int] = None
    sampled: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size >= 1, "a request needs >= 1 prompt token"

    # -- derived ----------------------------------------------------------

    @property
    def total_len(self) -> int:
        """Target sequence length: prompt + budgeted new tokens."""
        return int(self.prompt.size) + int(self.max_new_tokens)

    @property
    def deadline(self) -> Optional[int]:
        return (None if self.max_latency is None
                else self.arrival + int(self.max_latency))

    def known_tokens(self) -> np.ndarray:
        """Everything decodable by teacher forcing: the prompt plus every
        token sampled before a preemption — the re-admission 'prompt'."""
        if not self.sampled:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.sampled, np.int32)])

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def missed_deadline(self) -> Optional[bool]:
        """None until finished; then whether the SLO was violated."""
        if self.finished_at is None or self.deadline is None:
            return None
        return self.finished_at > self.deadline

    def queue_wait(self) -> Optional[int]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.arrival

    def ttft(self) -> Optional[int]:
        """Time to first token (steps from arrival)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival

    def tpot(self) -> Optional[float]:
        """Time per output token after the first (steps/token), preemption
        stalls included — the decode-phase SLO companion to TTFT.  None
        until finished with at least two sampled tokens."""
        if (self.finished_at is None or self.first_token_at is None
                or len(self.sampled) < 2):
            return None
        return ((self.finished_at - self.first_token_at)
                / (len(self.sampled) - 1))
