"""The paper's hash table as the paged-KV page table / allocator.

The linear-probing table IS the allocator: the table has one cell per
physical KV page, keyed by ``(seq_id, logical_page)``; *claiming cell i
allocates physical page i*.  The paper's operations map 1:1 onto the
serving runtime:

* ``insert`` — page allocation (one per sequence per ``page_size`` tokens);
  probe-order arbitration resolves races between concurrent allocations.
* wait-free ``lookup`` — the block-table read on EVERY decode step's
  critical path (kernels/probe is the Pallas fast path).
* ``delete`` — sequence eviction: all its pages become TOMBSTONEs, and
  **tombstone reuse** (the paper's headline) means freed page slots are
  re-claimed by later allocations directly — no compaction, no rebuild,
  no fragmentation sweep.  This is Proposition 2 operating as a memory
  allocator.

Key packing: key = seq_id * MAX_LOGICAL_PAGES + logical_page (28-bit key
space from core/encoding: seq_id < 2^17 with 2^11 logical pages covers
500k-token contexts at page_size 256).

Incremental block table (the decode hot path): the full ``lookup_pages``
read is O(B·max_pages) probed keys per call, but between two decode steps
at most the page-boundary crossings changed.  ``alloc_step_incremental``
therefore maintains a persistent ``block_table`` int32[B, max_pages] cache
by scatter — the per-token probe work drops to O(crossings) — while the
wait-free lookup stays the *authoritative* read used to (re)build the cache
on admission (``rebuild_block_table``), after a Section 4.3 rebuild, and in
the CI-only verification mode (``verify_block_table``).  Eviction must
invalidate the evicted lanes' rows (``invalidate_block_rows``) or a
re-admitted slot could read a reclaimed page.

Probe strategies: the ``PageTable`` facade binds one ``core/
probe_strategies`` strategy (``linear`` / ``robinhood`` / ``hopscotch``)
at construction and threads it through every operation — callers hold one
facade object (``for_strategy``) instead of plumbing a keyword through
every call site.  (The historical module-level free functions were removed
once the last in-repo callers migrated; the facade is the only API.)

The distributed flavour — hash-prefix sharding of the key space across
host groups, per-shard headroom, lazy incremental resize — lives one layer
up in ``serving/sharded_table.ShardedPageTable``, which routes to one
table-per-shard built from this module's primitives.
"""
from __future__ import annotations

import contextlib
import functools
import logging
from typing import Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.core import encoding as E
from repro.core.probe_strategies import get_strategy

logger = logging.getLogger(__name__)

MAX_LOGICAL_PAGES = 2048  # 2^11 -> 500k tokens at page_size 256

# ---------------------------------------------------------------------------
# Probe accounting (machine-independent perf counter).
#
# Counts keys submitted to table probe operations (insert/find/delete) by the
# page-table layer.  Only *concrete* (eager) calls count — under jit the
# counts are tracers and are skipped — which is exactly what the
# ``probes_per_token`` benchmark wants: a deterministic host-side replay.

PROBE_STATS = {"keys_probed": 0}


def probe_stats_reset() -> None:
    PROBE_STATS["keys_probed"] = 0


@contextlib.contextmanager
def probe_stats_scope() -> Iterator[dict]:
    """Scoped probe accounting: inside the ``with`` block the counter starts
    at 0 and counts only the scope's own (eager) probes; on exit the
    enclosing counter value is RESTORED exactly, so one batcher run / bench
    can never bleed counts into another (the PROBE_STATS lifecycle bug).
    Read the scoped count from the yielded dict *before* the block exits:

        with PT.probe_stats_scope() as ps:
            ...page-table calls...
            n = ps["keys_probed"]

    Scopes nest: each level sees only its own counts."""
    outer = PROBE_STATS["keys_probed"]
    PROBE_STATS["keys_probed"] = 0
    try:
        yield PROBE_STATS
    finally:
        PROBE_STATS["keys_probed"] = outer


def _note_probes(n) -> None:
    try:
        PROBE_STATS["keys_probed"] += int(n)
    except (TypeError, jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        pass  # traced: benchmark counters only apply to eager replays


def page_key(seq_ids, logical_pages):
    return (jnp.asarray(seq_ids, jnp.uint32) * jnp.uint32(MAX_LOGICAL_PAGES)
            + jnp.asarray(logical_pages, jnp.uint32))


class AllocStep(NamedTuple):
    """Result of one per-step allocation round.

    ``write_slot`` is -1 for lanes that must NOT write KV this step: inactive
    lanes (finished / padding slots) and lanes whose allocation ABORTed.  The
    -1 sentinel is a *refusal*, not an index — every consumer masks on
    ``write_slot >= 0`` before scattering (``paged.write_token_kv``), so an
    abort can never wrap into physical page -1 and corrupt another
    sequence's KV.  ``aborted`` surfaces the ABORT per lane so the engine /
    batcher can refuse the token and trigger the Section 4.3 rebuild path
    instead of silently serving garbage."""
    table: BT.HashTable
    write_slot: jnp.ndarray   # int32[B]
    aborted: jnp.ndarray      # bool[B]


class PageTableStats(NamedTuple):
    live_pages: jnp.ndarray
    tombstones: jnp.ndarray
    occupancy: jnp.ndarray


class Headroom(NamedTuple):
    """First-class occupancy/headroom view of the page pool (host ints —
    the admission controller's input).  With tombstone reuse (Prop. 2 as
    the allocator) a TOMBSTONE cell is immediately re-claimable, so the
    capacity that matters for admission is ``free_cells = n_pages -
    live_pages``: linear/robinhood ABORT only when every cell holds a live
    key.  Under hopscotch there are never tombstones — ``free_cells``
    counts EMPTY cells exactly — but displacement can fail before the pool
    is full, so ``slack`` carries the strategy's extra headroom requirement
    (``ProbeStrategy.forecast_slack``) for the forecaster's no-ABORT gate:
    admit only while ``demand + safety + slack <= free_cells``.
    ``occupancy`` keeps the paper's definition (non-EMPTY fraction, what
    forces rebuilds in NO-reuse designs) for comparison."""
    n_pages: int
    live_pages: int
    tombstones: int
    free_cells: int        # n_pages - live_pages (tombstones are reusable)
    live_fraction: float   # live_pages / n_pages — the abort-relevant load
    occupancy: float       # (live + tombstones) / n_pages (paper's metric)
    strategy: str = "linear"
    slack: int = 0         # strategy's forecast_slack(n_pages)


class PageTable:
    """Strategy-bound facade over the allocator.  Stateless apart from the
    static strategy string — table state stays a functional pytree passed
    in and returned, so one facade instance serves any number of pools and
    jit caches one program per strategy."""

    def __init__(self, strategy: str = "linear"):
        self._impl = get_strategy(strategy)  # validates the name eagerly
        self.strategy = strategy
        self._kernel_fallback_logged = False

    # -- construction / maintenance ------------------------------------

    def create_table(self, n_pages: int, seed: int = 0) -> BT.HashTable:
        return BT.create(n_pages, seed=seed, strategy=self.strategy)

    def rehash(self, table: BT.HashTable, n_pages: int,
               seed: Optional[int] = None
               ) -> Tuple[BT.HashTable, jnp.ndarray, jnp.ndarray,
                          jnp.ndarray]:
        """Section 4.3 rebuild, page-table flavoured: re-insert every live
        key into a fresh table of ``n_pages`` cells (a new seed by
        default).  Because the cell index IS the physical page, the caller
        must move the KV pages along with their keys: returns (table',
        old_slot[m], new_slot[m], live[m]) — the page permutation (padded
        entries have live=False)."""
        keys, n_live = BT.live_keys(table)
        live = jnp.arange(keys.shape[0]) < n_live
        fresh = BT.create(n_pages,
                          seed=(int(table.seed) + 1 if seed is None
                                else seed),
                          strategy=self.strategy)
        fresh, _ = BT.insert_batch(fresh, keys, active=live,
                                   strategy=self.strategy)
        _, old_slots = BT.find_batch(table, keys, live,
                                     strategy=self.strategy)
        _, new_slots = BT.find_batch(fresh, keys, live,
                                     strategy=self.strategy)
        return fresh, old_slots, new_slots, live

    # -- allocation -----------------------------------------------------

    def alloc_step(self, table: BT.HashTable, seq_ids, positions, *,
                   page_size: int, active=None) -> AllocStep:
        """Per decode step: allocate the page for each sequence's current
        position when it crosses a page boundary.

        ``active`` bool[B] (default all-True) masks lanes that are live:
        inactive lanes neither allocate (the phantom-page leak — a
        finished/padding lane would otherwise claim a real page every
        ``page_size`` steps until eviction) nor receive a
        ``write_slot``."""
        act = (jnp.ones(positions.shape, bool) if active is None
               else jnp.asarray(active, bool))
        page_idx = positions // page_size
        need_new = ((positions % page_size) == 0) & act
        keys = page_key(seq_ids, page_idx)
        table, ret = BT.insert_batch(table, keys, active=need_new,
                                     strategy=self.strategy)
        aborted = need_new & (ret == 2)
        found, slots = BT.find_batch(table, keys, strategy=self.strategy)
        _note_probes(jnp.sum(need_new) + positions.shape[0])
        # a miss means the allocator aborted (pool exhausted) — surface -1
        return AllocStep(table, jnp.where(found & act, slots, -1), aborted)

    def alloc_step_incremental(self, table: BT.HashTable, seq_ids,
                               positions, block_table, *, page_size: int,
                               active=None) -> Tuple[AllocStep, jnp.ndarray]:
        """``alloc_step`` with the incremental block-table cache: only the
        page-boundary crossings probe the table; every other lane's
        ``write_slot`` is served from ``block_table`` (int32[B, max_pages],
        -1 = absent).  Returns (AllocStep, block_table').

        Per-token probe work drops from O(B) to O(crossings); the crossing
        scatter keeps the cache equal to the authoritative wait-free lookup
        (``verify_block_table``).  On ABORT the crossing entry is written
        as -1 — the cache must never retain a stale slot for a page the
        allocator refused (a re-admitted lane's row could otherwise point
        at a reclaimed physical page)."""
        B = positions.shape[0]
        act = (jnp.ones(positions.shape, bool) if active is None
               else jnp.asarray(active, bool))
        page_idx = (positions // page_size).astype(jnp.int32)
        need_new = ((positions % page_size) == 0) & act
        keys = page_key(seq_ids, page_idx)
        table, ret = BT.insert_batch(table, keys, active=need_new,
                                     strategy=self.strategy)
        aborted = need_new & (ret == 2)
        found, slots = BT.find_batch(table, keys, active=need_new,
                                     strategy=self.strategy)
        _note_probes(2 * jnp.sum(need_new))
        fresh_slot = jnp.where(found & need_new, slots, -1)

        max_pages = block_table.shape[1]
        rows = jnp.arange(B, dtype=jnp.int32)
        cached = block_table[rows, jnp.clip(page_idx, 0, max_pages - 1)]
        write_slot = jnp.where(need_new, fresh_slot,
                               jnp.where(act, cached, -1))
        block_table = block_table.at[
            rows, jnp.where(need_new, page_idx, max_pages)].set(
            fresh_slot, mode="drop")
        return AllocStep(table, write_slot, aborted), block_table

    def prefill_alloc(self, table: BT.HashTable, seq_ids, lengths, *,
                      page_size: int, max_pages: int
                      ) -> Tuple[BT.HashTable, jnp.ndarray]:
        """Allocate all pages for freshly prefilling sequences.  Returns
        (table', slots [B, max_pages])."""
        B = seq_ids.shape[0]
        logical = jnp.arange(max_pages, dtype=jnp.uint32)
        keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
        need = (logical[None, :] * page_size < lengths[:, None]).reshape(-1)
        table, _ = BT.insert_batch(table, keys, active=need,
                                   strategy=self.strategy)
        found, slots = BT.find_batch(table, keys, strategy=self.strategy)
        slots = jnp.where(found & need, slots, -1)
        return table, slots.reshape(B, max_pages)

    # -- eviction -------------------------------------------------------

    def free_sequences(self, table: BT.HashTable, seq_ids, positions, *,
                       page_size: int, max_pages: int,
                       active=None) -> BT.HashTable:
        """Evict sequences: delete all their page keys -> slots immediately
        reusable by subsequent alloc_steps (no rebuild).  Linear/robinhood
        leave tombstones (reused, Prop. 2); hopscotch reclaims the cells to
        EMPTY outright."""
        B = seq_ids.shape[0]
        logical = jnp.arange(max_pages, dtype=jnp.uint32)
        keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
        act = jnp.broadcast_to(
            (logical[None, :] <= positions[:, None] // page_size) &
            (jnp.ones((B, 1), bool) if active is None
             else jnp.asarray(active, bool)[:, None]),
            (B, max_pages)).reshape(-1)
        table, _ = BT.delete_batch(table, keys, active=act,
                                   strategy=self.strategy)
        _note_probes(jnp.sum(act))
        return table

    # -- reads ----------------------------------------------------------

    def lookup_pages(self, table: BT.HashTable, seq_ids, positions, *,
                     page_size: int, max_pages: int) -> jnp.ndarray:
        """Wait-free block-table read: physical slot of every logical page
        of every sequence (-1 where absent/not-yet-needed).
        [B, max_pages]."""
        B = seq_ids.shape[0]
        logical = jnp.arange(max_pages, dtype=jnp.uint32)
        keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
        found, slots = BT.find_batch(table, keys, strategy=self.strategy)
        _note_probes(B * max_pages)
        slots = slots.reshape(B, max_pages)
        found = found.reshape(B, max_pages)
        live = logical[None, :] <= (positions[:, None] // page_size)
        return jnp.where(found & live, slots, -1)

    def rebuild_block_table(self, table: BT.HashTable, seq_ids,
                            max_pages: int, *,
                            use_kernel: bool = False) -> jnp.ndarray:
        """(Re)build block-table rows from the authoritative wait-free
        lookup — used on admission (a prefilled sequence brings pages with
        it), after a Section 4.3 ``rehash`` (every slot moved), and by the
        verification mode.  Unlike ``lookup_pages`` this caches every
        present page regardless of the current position — liveness is
        applied at read time by ``block_table_slots``.

        ``use_kernel=True`` serves the bulk lookup through the Pallas
        software-pipelined probe kernel (``kernels/probe``; unresolved tail
        falls back to the same ``BT.find_batch`` oracle in-graph) — bitwise
        the same rows, one VMEM-tiled sweep instead of B·max_pages gathers.
        The kernel assumes the linear probe order: for other strategies the
        request falls back to the jnp oracle, LOGGED (and surfaced by
        ``engine.fallback_report`` / the dryrun ``probe_strategy`` cell
        field — never silent)."""
        B = seq_ids.shape[0]
        logical = jnp.arange(max_pages, dtype=jnp.uint32)
        keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
        if use_kernel and not self._impl.kernel_supported:
            if not self._kernel_fallback_logged:
                logger.warning(
                    "probe kernel fallback: strategy %r is not supported "
                    "by the Pallas probe kernel (linear probe order); "
                    "serving rebuild_block_table from the jnp oracle",
                    self.strategy)
                self._kernel_fallback_logged = True
            use_kernel = False
        if use_kernel:
            from repro.kernels.probe import ops as PK
            found, slots = PK.probe_lookup(
                table, keys, interpret=jax.default_backend() != "tpu",
                strategy=self.strategy)
        else:
            found, slots = BT.find_batch(table, keys,
                                         strategy=self.strategy)
        _note_probes(B * max_pages)
        return jnp.where(found, slots, -1).reshape(B, max_pages)

    @staticmethod
    def block_table_slots(block_table, positions, *,
                          page_size: int) -> jnp.ndarray:
        """The per-step block-table read, cache flavoured: same
        [B, max_pages] view as ``lookup_pages`` (-1 where absent/not-yet-
        needed) with ZERO probes — pure elementwise masking of the cached
        rows."""
        max_pages = block_table.shape[1]
        logical = jnp.arange(max_pages, dtype=jnp.int32)
        live = logical[None, :] <= (positions[:, None] // page_size)
        return jnp.where(live & (block_table >= 0), block_table, -1)

    @staticmethod
    def invalidate_block_rows(block_table, mask) -> jnp.ndarray:
        """Evict lanes from the cache: rows where ``mask`` is True become
        all -1.  MUST be called when a lane's sequence is evicted/freed —
        the slot's next occupant would otherwise read the reclaimed
        physical pages."""
        return jnp.where(jnp.asarray(mask, bool)[:, None],
                         jnp.int32(-1), block_table)

    def verify_block_table(self, table: BT.HashTable, seq_ids, positions,
                           block_table, *, page_size: int) -> jnp.ndarray:
        """CI-only verification mode: mismatch count between the
        incremental cache and the authoritative wait-free lookup (0 = cache
        coherent)."""
        max_pages = block_table.shape[1]
        ref = self.lookup_pages(table, seq_ids, positions,
                                page_size=page_size, max_pages=max_pages)
        got = self.block_table_slots(block_table, positions,
                                     page_size=page_size)
        return jnp.sum(got != ref)

    # -- accounting -----------------------------------------------------

    @staticmethod
    def stats(table: BT.HashTable) -> PageTableStats:
        return PageTableStats(live_pages=table.num_keys,
                              tombstones=table.num_tombs,
                              occupancy=BT.occupancy(table))

    def forecast_slack(self, n_pages: int) -> int:
        """Extra free cells the forecaster must hold for this strategy's
        no-ABORT guarantee (0 for linear/robinhood — Prop. 2 is exact)."""
        return self._impl.forecast_slack(n_pages)

    @staticmethod
    def probe_p99(table: BT.HashTable, q: float = 99.0) -> float:
        """Host-side probe-length percentile of the CURRENT pool: for every
        live key, its displacement from the hash slot (mod table size) — the
        linear-probe distance a lookup walks.  Eager/NumPy (pulls the cell
        array once); telemetry/report-path only, never inside jit."""
        tab = np.asarray(table.table)
        occ = (tab != BT.E.EMPTY) & (tab != BT.E.TOMBSTONE)
        idx = np.nonzero(occ)[0]
        if not idx.size:
            return 0.0
        hv = np.asarray(BT._hash(
            table, jnp.asarray(np.asarray(BT.E.dec_key(tab[idx]),
                                          np.uint32))))
        d = (idx - hv) % tab.shape[0]
        return float(np.percentile(d, q))

    def headroom(self, table: BT.HashTable) -> Headroom:
        """Synchronous (host) headroom read.  One device sync for the two
        counters — cheap next to the once-per-K-tokens megastep sync, and
        the proactive scheduler needs concrete numbers to decide
        evict/grow."""
        m = BT.size(table)
        live = int(table.num_keys)
        tombs = int(table.num_tombs)
        return Headroom(n_pages=m, live_pages=live, tombstones=tombs,
                        free_cells=m - live,
                        live_fraction=live / max(m, 1),
                        occupancy=(live + tombs) / max(m, 1),
                        strategy=self.strategy,
                        slack=self.forecast_slack(m))


@functools.lru_cache(maxsize=None)
def for_strategy(strategy: str = "linear") -> PageTable:
    """The shared per-strategy facade: one instance per strategy string, so
    jit sees stable bound methods and log-once fallback state persists
    across call sites (engine, batcher, benchmarks)."""
    return PageTable(strategy)
