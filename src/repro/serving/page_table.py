"""The paper's hash table as the paged-KV page table / allocator.

The linear-probing table IS the allocator: the table has one cell per
physical KV page, keyed by ``(seq_id, logical_page)``; *claiming cell i
allocates physical page i*.  The paper's operations map 1:1 onto the
serving runtime:

* ``insert`` — page allocation (one per sequence per ``page_size`` tokens);
  probe-order arbitration resolves races between concurrent allocations.
* wait-free ``lookup`` — the block-table read on EVERY decode step's
  critical path (kernels/probe is the Pallas fast path).
* ``delete`` — sequence eviction: all its pages become TOMBSTONEs, and
  **tombstone reuse** (the paper's headline) means freed page slots are
  re-claimed by later allocations directly — no compaction, no rebuild,
  no fragmentation sweep.  This is Proposition 2 operating as a memory
  allocator.

Key packing: key = seq_id * MAX_LOGICAL_PAGES + logical_page (28-bit key
space from core/encoding: seq_id < 2^17 with 2^11 logical pages covers
500k-token contexts at page_size 256).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import batched as BT
from repro.core import encoding as E

MAX_LOGICAL_PAGES = 2048  # 2^11 -> 500k tokens at page_size 256


def page_key(seq_ids, logical_pages):
    return (jnp.asarray(seq_ids, jnp.uint32) * jnp.uint32(MAX_LOGICAL_PAGES)
            + jnp.asarray(logical_pages, jnp.uint32))


def create_table(n_pages: int, seed: int = 0) -> BT.HashTable:
    return BT.create(n_pages, seed=seed)


class AllocStep(NamedTuple):
    """Result of one per-step allocation round.

    ``write_slot`` is -1 for lanes that must NOT write KV this step: inactive
    lanes (finished / padding slots) and lanes whose allocation ABORTed.  The
    -1 sentinel is a *refusal*, not an index — every consumer masks on
    ``write_slot >= 0`` before scattering (``paged.write_token_kv``), so an
    abort can never wrap into physical page -1 and corrupt another
    sequence's KV.  ``aborted`` surfaces the ABORT per lane so the engine /
    batcher can refuse the token and trigger the Section 4.3 rebuild path
    instead of silently serving garbage."""
    table: BT.HashTable
    write_slot: jnp.ndarray   # int32[B]
    aborted: jnp.ndarray      # bool[B]


def alloc_step(table: BT.HashTable, seq_ids, positions, *,
               page_size: int, active=None) -> AllocStep:
    """Per decode step: allocate the page for each sequence's current
    position when it crosses a page boundary.

    ``active`` bool[B] (default all-True) masks lanes that are live: inactive
    lanes neither allocate (the phantom-page leak — a finished/padding lane
    would otherwise claim a real page every ``page_size`` steps until
    eviction) nor receive a ``write_slot``."""
    act = (jnp.ones(positions.shape, bool) if active is None
           else jnp.asarray(active, bool))
    page_idx = positions // page_size
    need_new = ((positions % page_size) == 0) & act
    keys = page_key(seq_ids, page_idx)
    table, ret = BT.insert_batch(table, keys, active=need_new)
    aborted = need_new & (ret == 2)
    found, slots = BT.find_batch(table, keys)
    # a miss means the allocator aborted (pool exhausted) — surface -1
    return AllocStep(table, jnp.where(found & act, slots, -1), aborted)


def rehash(table: BT.HashTable, n_pages: int, seed: Optional[int] = None
           ) -> Tuple[BT.HashTable, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Section 4.3 rebuild, page-table flavoured: re-insert every live key
    into a fresh table of ``n_pages`` cells (a new seed by default).  Because
    the cell index IS the physical page, the caller must move the KV pages
    along with their keys: returns (table', old_slot[m], new_slot[m],
    live[m]) — the page permutation (padded entries have live=False)."""
    keys, n_live = BT.live_keys(table)
    live = jnp.arange(keys.shape[0]) < n_live
    fresh = BT.create(n_pages,
                      seed=(int(table.seed) + 1 if seed is None else seed))
    fresh, _ = BT.insert_batch(fresh, keys, active=live)
    _, old_slots = BT.find_batch(table, keys, live)
    _, new_slots = BT.find_batch(fresh, keys, live)
    return fresh, old_slots, new_slots, live


def lookup_pages(table: BT.HashTable, seq_ids, positions, *,
                 page_size: int, max_pages: int) -> jnp.ndarray:
    """Wait-free block-table read: physical slot of every logical page of
    every sequence (-1 where absent/not-yet-needed).  [B, max_pages]."""
    B = seq_ids.shape[0]
    logical = jnp.arange(max_pages, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
    found, slots = BT.find_batch(table, keys)
    slots = slots.reshape(B, max_pages)
    found = found.reshape(B, max_pages)
    live = logical[None, :] <= (positions[:, None] // page_size)
    return jnp.where(found & live, slots, -1)


def free_sequences(table: BT.HashTable, seq_ids, positions, *,
                   page_size: int, max_pages: int,
                   active=None) -> BT.HashTable:
    """Evict sequences: delete all their page keys -> tombstones -> slots
    immediately reusable by subsequent alloc_steps (no rebuild)."""
    B = seq_ids.shape[0]
    logical = jnp.arange(max_pages, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
    act = jnp.broadcast_to(
        (logical[None, :] <= positions[:, None] // page_size) &
        (jnp.ones((B, 1), bool) if active is None
         else jnp.asarray(active, bool)[:, None]),
        (B, max_pages)).reshape(-1)
    table, _ = BT.delete_batch(table, keys, active=act)
    return table


def prefill_alloc(table: BT.HashTable, seq_ids, lengths, *,
                  page_size: int, max_pages: int
                  ) -> Tuple[BT.HashTable, jnp.ndarray]:
    """Allocate all pages for freshly prefilling sequences.  Returns
    (table', slots [B, max_pages])."""
    B = seq_ids.shape[0]
    logical = jnp.arange(max_pages, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], logical[None, :]).reshape(-1)
    need = (logical[None, :] * page_size < lengths[:, None]).reshape(-1)
    table, _ = BT.insert_batch(table, keys, active=need)
    found, slots = BT.find_batch(table, keys)
    slots = jnp.where(found & need, slots, -1)
    return table, slots.reshape(B, max_pages)


class PageTableStats(NamedTuple):
    live_pages: jnp.ndarray
    tombstones: jnp.ndarray
    occupancy: jnp.ndarray


def stats(table: BT.HashTable) -> PageTableStats:
    return PageTableStats(live_pages=table.num_keys,
                          tombstones=table.num_tombs,
                          occupancy=BT.occupancy(table))
