"""Decode engines: one ``serve_step`` per architecture family, plus the
K-token ``make_serve_megastep`` (one dispatch, K greedy tokens).

The hash-table page table (serving/page_table) is consulted ONCE per step
(alloc + block-table read); page locality is compacted ONCE per chip
(serving/paged.compact_local); every attention layer then reuses the same
compacted page list.  The block-table read is served from the persistent
``state["block_table"]`` cache, scatter-updated at page-boundary crossings
by ``PageTable.alloc_step_incremental`` — O(crossings) probed keys per token
instead of the old O(B·max_pages) full re-probe — while the paper's
wait-free ``lookup_pages`` remains the authoritative read for admission,
Section 4.3 rebuilds, and the CI verification mode
(``PageTable.verify_block_table``).

The megastep fuses K decode tokens into one ``jax.lax.scan``: greedy
sampling runs in-graph (token t+1 = argmax of token t's logits), page
allocation runs inside the scan, and done/abort conditions latch into
on-device flags, so the host syncs once per K tokens.  A lane that ABORTs
mid-megastep freezes (pos, pending token, recurrent state) and the batcher
re-issues the refused suffix after ``rebuild_page_table``.  The
``forced``/``forced_mask`` inputs teacher-force fed tokens (CHUNKED
PREFILL under the same dispatch budget — see ``_mega_scan`` and
``repro.serving.sched``); ``make_decode_state(n_pages=...)`` overcommits
the pool and ``decode_headroom`` exposes the occupancy the scheduler's
forecaster consumes.

Sharding, gspmd baseline (``serve_rules``): activations replicated (decode
activations are KB-scale), weights TP-sharded over ``model``, page pools
sharded over every mesh axis, SSM/ring state sharded over batch.  The paged
attention op is a fully-manual shard_map; everything else is GSPMD.

``tp_impl="manual"`` (``serve_manual_rules``): ONE fully-manual shard_map
over every mesh axis covers the whole step — embed, the once-per-step
page-table alloc + wait-free lookup + per-chip compaction, every layer's
attention/MLP/MoE, and the read-out.  Layout: KV pools page-sharded over
(pod, data) and *head*-sharded over ``model`` (each chip attends its own
heads end-to-end — no cross-model K/V gather), page-table metadata
replicated (every chip runs the identical lookup), weights Megatron
column/row-parallel with one psum after attention and one after the
MLP/MoE.  When the model axis is WIDER than ``n_kv`` (e.g. kv=8 on the
16-wide production mesh), KV heads are REPLICATED across the surplus width
(``dist/tp.decode_kv_rep``): pools/ring state carry ``n_kv·rep`` tiled
heads so each chip still keeps exactly one resident head.  Local-window
(gemma3) ring layers and the hybrid family's Mamba backbone + shared
attention block run INSIDE the same region (ring/ssm state per-lane; the
mamba math shards its per-head inner dims over ``model`` when
``dist/tp.decode_ssm_tp`` passes — replicated redundant compute
otherwise).  Only ssm
(attention-free) and encdec remain on the gspmd step — every fallback is
logged, never silent (``_manual_decode_reason``).

Liveness (all paths): ``state["active"]`` masks finished/padding lanes out
of page allocation and freezes their ``pos`` (otherwise each dead lane
leaks a phantom page every ``page_size`` steps); ``state["aborted"]``
latches lanes whose allocation ABORTed (pool exhausted) — their token is
refused (no KV write, pos frozen) until the caller evicts or runs the
Section 4.3 ``rebuild_page_table``.
"""
from __future__ import annotations

import functools
import logging
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import ctx
from repro.dist import tp as TP
from repro.dist.compat import shard_map
from repro.models import hybrid as HY
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import nn
from repro.models import ssm
from repro.obs import counters as OC
from repro.serving import page_table as PT
from repro.serving import paged
from repro.core import batched as BT
from repro.kernels.fused_decode.fused import fused_decode_kernel

DEFAULT_PAGE_SIZE = 256

logger = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# Mesh helpers.

def _mesh_axes(rules):
    if rules is None:
        return ()
    return tuple(a for a in ("pod", "data", "model") if a in rules.mesh.shape)


def _n_chips(rules) -> int:
    if rules is None:
        return 1
    n = 1
    for a in _mesh_axes(rules):
        n *= rules.mesh.shape[a]
    return n


def _chip_idx(axes, mesh):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _pd_axes(rules):
    """Mesh axes the page dim shards over in the fused manual decode layout
    (everything but ``model``, which shards KV heads instead)."""
    return tuple(a for a in ("pod", "data") if a in rules.mesh.shape)


# The two genuinely unsupported families — everything else (dense incl.
# the gemma3 local-window pattern, moe, vlm, hybrid) takes the fused path.
_MANUAL_UNSUPPORTED_FAMILY = {
    "ssm": "attention-free SSM stack: no model-axis work in the region",
    "encdec": "cross-attention decode state not yet inside the fused region",
}


def _manual_decode_reason(cfg, rules) -> Optional[str]:
    """Why ``tp_impl="manual"`` decode falls back to gspmd — None when the
    fused region applies."""
    fam = _MANUAL_UNSUPPORTED_FAMILY.get(cfg.family)
    if fam is not None:
        return fam
    return TP.decode_manual_unsupported(cfg, rules)


def _manual_decode_ok(cfg, rules) -> bool:
    """The fused manual-TP decode region applies (family supported AND the
    shape gate dist/tp.decode_manual_tp passes)."""
    return _manual_decode_reason(cfg, rules) is None


def _fused_kernel_reason(cfg, rules) -> Optional[str]:
    """Why decode attention does NOT run as the one-dispatch fused
    probe+paged-attention Pallas kernel (kernels/fused_decode) — None when
    it does.  Evaluated for whichever serve path (manual region or gspmd)
    the step factory actually picks; a non-None reason with
    ``cfg.fused_kernel=True`` is logged by the factories and recorded in
    dry-run artifacts (``fused_kernel`` field), never silent."""
    if not cfg.fused_kernel:
        return "off (cfg.fused_kernel=False)"
    if cfg.family == "ssm":
        return "attention-free SSM stack: no paged decode attention"
    if cfg.family == "encdec":
        return "cross-attention decode state not wired to the fused kernel"
    if rules is not None and _manual_decode_ok(cfg, rules):
        if TP.decode_kv_rep(cfg, rules.mesh.shape["model"]) != 1:
            return ("kv_rep>1: replicated-KV manual layout keeps the "
                    "two-dispatch per-chip attend path")
    return None


def _fused_kernel_ok(cfg, rules) -> bool:
    return _fused_kernel_reason(cfg, rules) is None


def _probe_strategy_reason(cfg, rules=None) -> Optional[str]:
    """Why ``cfg.probe_strategy`` runs without full fast-path acceleration —
    None when fully served.  The strategy SEMANTICS (probe order, claim
    arbitration, deletion mode, metadata) are ALWAYS honoured by the jnp
    allocator — the scheduler's accounting depends on them — so unlike
    ``tp_impl``/``fused_kernel`` this gate never swaps the strategy out; it
    reports which accelerated path degrades to the oracle (logged by the
    step factories, recorded per-cell by dryrun via ``fallback_report``)."""
    from repro.core.probe_strategies import get_strategy
    impl = get_strategy(cfg.probe_strategy)  # raises on unknown names
    if not impl.kernel_supported:
        return ("Pallas probe kernel assumes the linear probe order: bulk "
                "block-table rebuilds serve from the jnp oracle")
    return None


def _pt(cfg) -> PT.PageTable:
    """The strategy-bound page-table facade for this config."""
    return PT.for_strategy(cfg.probe_strategy)


def fallback_report(cfg, rules=None) -> Dict[str, str]:
    """Every gated fast-path fallback in ONE structure: the single source
    consumed by dry-run cell meta and the ``--expect-*`` CI gates (the step
    factories log from the same reason functions, so a logged fallback can
    never diverge from the recorded one).  Values are ``"ok"`` or the
    fallback reason; ``probe_strategy`` is prefixed with the requested
    strategy name so artifacts show WHAT ran, not just whether it
    degraded."""
    manual = _manual_decode_reason(cfg, rules) if rules is not None else None
    strat_reason = _probe_strategy_reason(cfg, rules)
    return {
        "decode_tp": "ok" if manual is None else manual,
        "fused_kernel": ("ok" if _fused_kernel_ok(cfg, rules)
                         else _fused_kernel_reason(cfg, rules)),
        "probe_strategy": (f"{cfg.probe_strategy}: ok"
                           if strat_reason is None
                           else f"{cfg.probe_strategy}: {strat_reason}"),
    }


def _kernel_interpret() -> bool:
    """Pallas kernels run compiled on TPU, interpreted elsewhere (CI's fake
    CPU devices) — resolved at trace time, never a silent wrong-backend."""
    return jax.default_backend() != "tpu"


def _local_block_table(bt, chip_idx, npr: int):
    """Chip-local view of the RAW incremental block table for the fused
    kernel: entries this chip owns (block distribution ``slot // npr ==
    chip``, identical to ``paged.compact_local``/``write_token_kv``) become
    local pool rows, everything else -1.  Liveness (``p·PS <= pos``) is
    enforced in-kernel from ``positions`` — no materialized slots view, no
    per-chip compaction pass."""
    mine = (bt >= 0) & (bt // npr == chip_idx)
    return jnp.where(mine, bt % npr, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# State construction.

def plan_pages(cfg, B: int, S_max: int, page_size: int, n_chips: int):
    max_pages = -(-S_max // page_size)
    n_pages = paged.round_pages(int(B * max_pages * 1.25) + n_chips,
                                n_chips)
    return max_pages, n_pages


def _n_attn_layers(cfg) -> Tuple[int, int]:
    """(paged/global attention layers, ring/local attention layers)."""
    if cfg.family == "ssm":
        return 0, 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.shared_attn_every, 0
    if cfg.pattern_local:
        g = cfg.pattern_local + 1
        return cfg.num_layers // g, cfg.num_layers - cfg.num_layers // g
    return cfg.num_layers, 0


def make_decode_state(cfg, B: int, S_max: int, *, rules=None,
                      page_size: int = DEFAULT_PAGE_SIZE,
                      n_pages: Optional[int] = None,
                      abstract: bool = False) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Decode-state pytree (+ logical axes).  ``abstract=True`` builds the
    pytree under eval_shape — nothing is allocated (dry-run states can be
    hundreds of GB).

    ``n_pages`` overrides the default worst-case pool plan (``plan_pages``:
    1.25x of B·max_pages): a serving deployment deliberately OVERCOMMITS
    the pool (most sequences finish early), betting on the scheduler's
    admission control / proactive headroom to keep the live set bounded —
    the pool can always be grown later via ``rebuild_page_table``.  The
    value is rounded up to the mesh's chip count (page-shard
    divisibility)."""
    n_chips = _n_chips(rules)
    dtype = cfg.activation_dtype()
    if n_pages is None:
        maxP, n_pages = plan_pages(cfg, B, S_max, page_size, n_chips)
    else:
        maxP = -(-S_max // page_size)
        n_pages = paged.round_pages(int(n_pages), n_chips)
    n_paged, n_ring = _n_attn_layers(cfg)
    manual_tp = rules is not None and _manual_decode_ok(cfg, rules)
    # fused-manual layout with a model axis wider than n_kv: the pool/ring
    # head dim is physically tiled to n_kv·rep so the "kv" logical axis
    # divides the mesh and every chip keeps exactly one resident head copy
    kv_rep = (TP.decode_kv_rep(cfg, rules.mesh.shape["model"])
              if manual_tp else 1)
    n_kv_st = cfg.n_kv * kv_rep

    def build() -> Dict[str, Any]:
        state: Dict[str, Any] = {
            "pos": jnp.zeros((B,), jnp.int32),
            "seq_ids": jnp.arange(B, dtype=jnp.int32),
            "active": jnp.ones((B,), bool),
            "aborted": jnp.zeros((B,), bool),
        }
        if n_paged:
            state["table"] = _pt(cfg).create_table(n_pages)
            # incremental block-table cache: scatter-updated at page-boundary
            # crossings, (re)built from the wait-free lookup on admission /
            # rebuild only (see page_table.alloc_step_incremental)
            state["block_table"] = jnp.full((B, maxP), -1, jnp.int32)
            kv_dtype = (jnp.int8 if cfg.kv_cache_dtype == "int8"
                        else dtype)
            state["pools"] = paged.make_pools(n_paged, n_pages, page_size,
                                              n_kv_st, cfg.hd, kv_dtype)
            if cfg.kv_cache_dtype == "int8":
                state["pool_scales"] = paged.make_pool_scales(
                    n_paged, n_pages, page_size, n_kv_st)
        if n_ring:
            w = cfg.local_window
            state["ring_k"] = jnp.zeros((n_ring, B, w, n_kv_st, cfg.hd),
                                        dtype)
            state["ring_v"] = jnp.zeros((n_ring, B, w, n_kv_st, cfg.hd),
                                        dtype)
            state["ring_pos"] = jnp.full((B, w), -1, jnp.int32)
        if cfg.family in ("ssm", "hybrid"):
            one = ssm.init_mamba_state(cfg, B, dtype)
            state["ssm"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (cfg.num_layers,) + x.shape) + 0, one)
        if cfg.family == "encdec":
            S_src = max(S_max // 8, 1)
            state["cross_k"] = jnp.zeros(
                (cfg.num_layers, B, S_src, cfg.n_kv, cfg.hd), dtype)
            state["cross_v"] = jnp.zeros(
                (cfg.num_layers, B, S_src, cfg.n_kv, cfg.hd), dtype)
        if getattr(cfg, "telemetry", False):
            # on-device counter plane (obs/counters.py): rides the megastep
            # scan, read out at the existing per-K host sync.  When the knob
            # is off the leaf does not exist and every update site below is
            # skipped — identity fast path, bitwise parity with
            # pre-telemetry programs (tests/test_obs.py).
            state["counters"] = OC.Counters.zeros()
        return state

    axes: Dict[str, Any] = {"pos": (None,), "seq_ids": (None,),
                            "active": (None,), "aborted": (None,)}
    if n_paged:
        axes["table"] = BT.HashTable(table=(None,), num_keys=(),
                                     num_tombs=(), seed=(), meta=(None,))
        axes["block_table"] = (None, None)
        pool_ax = paged.POOL_AXES_TP if manual_tp else paged.POOL_AXES
        axes["pools"] = paged.PagedPools(k=pool_ax, v=pool_ax)
        if cfg.kv_cache_dtype == "int8":
            sc_ax = (paged.POOL_SCALE_AXES_TP if manual_tp
                     else paged.POOL_SCALE_AXES)
            axes["pool_scales"] = paged.PoolScales(k=sc_ax, v=sc_ax)
    if n_ring:
        # fused manual region: ring heads over model (batch replicated —
        # activations in the region are); gspmd: per-sequence over data
        ring_ax = (("layer", None, None, "kv", None) if manual_tp
                   else ("layer", "batch", None, "kv", None))
        axes["ring_k"] = ring_ax
        axes["ring_v"] = ring_ax
        axes["ring_pos"] = (None, None) if manual_tp else ("batch", None)
    if cfg.family in ("ssm", "hybrid"):
        is_ax = lambda x: (isinstance(x, tuple)
                           and not isinstance(x, ssm.MambaState)
                           and all(e is None or isinstance(e, str)
                                   for e in x))
        # fused manual region: ssm state head-sharded over model when the
        # decode_ssm_tp gate passes (batch replicated — activations in the
        # region are), replicated redundant compute otherwise
        ssm_tp = (manual_tp and cfg.family == "hybrid"
                  and TP.decode_ssm_tp(cfg, rules.mesh.shape["model"]))
        if manual_tp:
            axes["ssm"] = jax.tree.map(
                lambda ax: ("layer",) + tuple(
                    (a if (ssm_tp and a != "batch") else None) for a in ax),
                ssm.MAMBA_STATE_AXES, is_leaf=is_ax)
        else:
            axes["ssm"] = jax.tree.map(
                lambda ax: ("layer",) + tuple(ax),
                ssm.MAMBA_STATE_AXES, is_leaf=is_ax)
    if cfg.family == "encdec":
        axes["cross_k"] = ("layer", "batch", None, "kv", None)
        axes["cross_v"] = ("layer", "batch", None, "kv", None)
    if getattr(cfg, "telemetry", False):
        axes["counters"] = OC.Counters.axes()

    state = jax.eval_shape(build) if abstract else build()
    return state, axes


def rebuild_page_table(state: Dict[str, Any], *, n_pages: Optional[int] = None,
                       seed: Optional[int] = None,
                       use_kernel: bool = False,
                       strategy: str = "linear") -> Dict[str, Any]:
    """Section 4.3 ABORT recovery, live in serving: re-hash the page table
    (into ``n_pages`` cells — pass a larger pool to actually gain capacity;
    with tombstone reuse a same-size rebuild only changes the seed, since
    the reuse table aborts only when every cell holds a live key) and MOVE
    the physical KV pages to their keys' new slots — the cell index IS the
    page, so the pages must follow the re-hash.  Clears ``aborted``.

    Host-side, outside jit: aborts are rare (true pool exhaustion), the
    rebuild cost is amortized exactly as in the paper.  ``n_pages`` must
    keep the pool divisible by the mesh's chip/page-shard count."""
    table = state["table"]
    pt = PT.for_strategy(strategy)
    # metadata-carrying strategies (hopscotch) and metadata-free ones build
    # different meta leaves: rebuilding with the wrong strategy would
    # silently corrupt the table
    if (table.meta.size > 0) != (pt.create_table(1).meta.size > 0):
        raise ValueError(
            f"rebuild_page_table: state's table metadata does not match "
            f"strategy {strategy!r} — pass the strategy the state was "
            f"built with (cfg.probe_strategy)")
    m = BT.size(table)
    new_m = m if n_pages is None else n_pages
    fresh, old_slots, new_slots, live = pt.rehash(table, new_m, seed)
    if bool(jnp.any(live & (new_slots < 0))):
        # a live key failed to land (n_pages smaller than the live set):
        # proceeding would orphan pages and wrap dst=-1 into the last row
        raise ValueError(
            f"rebuild_page_table: {int(jnp.sum(live & (new_slots < 0)))} "
            f"live pages do not fit in n_pages={new_m}")

    def move(pool, fill):
        shp = pool.shape[:1] + (new_m,) + pool.shape[2:]
        src = jnp.where(live, old_slots, 0)
        dst = jnp.where(live, new_slots, new_m)      # OOB -> dropped
        return jnp.full(shp, fill, pool.dtype).at[:, dst].set(
            pool[:, src], mode="drop")

    state = dict(state)
    state["table"] = fresh
    state["pools"] = paged.PagedPools(k=move(state["pools"].k, 0),
                                      v=move(state["pools"].v, 0))
    if "pool_scales" in state:
        state["pool_scales"] = paged.PoolScales(
            k=move(state["pool_scales"].k, 1),
            v=move(state["pool_scales"].v, 1))
    if "block_table" in state:
        # every slot moved: rebuild the incremental cache from the fresh
        # table via the authoritative wait-free lookup
        state["block_table"] = pt.rebuild_block_table(
            fresh, state["seq_ids"], state["block_table"].shape[1],
            use_kernel=use_kernel)
    state["aborted"] = jnp.zeros_like(state["aborted"])
    return state


def decode_headroom(state: Dict[str, Any],
                    strategy: str = "linear") -> Optional[PT.Headroom]:
    """First-class occupancy/headroom read of a decode state's page pool
    (None for attention-free families) — the proactive scheduler's
    observation input.  ``strategy`` fills the per-strategy ``slack`` field
    the forecaster adds to its no-ABORT gate.  See
    ``page_table.headroom``."""
    if "table" not in state:
        return None
    return PT.for_strategy(strategy).headroom(state["table"])


# ---------------------------------------------------------------------------
# The paged attention op (shard_map wrapper around serving/paged).

def _rope_single(cfg, x, positions, mrope=None):
    """x [B,H,hd] one token per seq at ``positions`` [B]."""
    x4 = x[:, None]                                  # [B,1,H,hd]
    if mrope is not None and cfg.mrope_sections:
        out = L.apply_mrope(x4, mrope, cfg.mrope_sections, cfg.rope_theta)
    else:
        out = L.apply_rope(x4, positions[:, None], cfg.rope_theta)
    return out[:, 0]


def _paged_attn_chip(cfg, x, ap, pool_k_l, pool_v_l, scales_l, lp_tree,
                     write_slot, positions, mrope, bt, *, axes_names, mesh,
                     page_size, kv_sharded, q_sharded, fused=False,
                     interpret=False):
    """Runs per chip (inside shard_map or standalone)."""
    B = x.shape[0]
    npr = pool_k_l.shape[0]
    chip = _chip_idx(axes_names, mesh) if axes_names else jnp.int32(0)

    q, k, v = L.attn_qkv_decode(ap, x[:, 0])
    if axes_names and q_sharded:
        q = jax.lax.all_gather(q, "model", axis=1, tiled=True)
    if axes_names and kv_sharded:
        k = jax.lax.all_gather(k, "model", axis=1, tiled=True)
        v = jax.lax.all_gather(v, "model", axis=1, tiled=True)
    q = _rope_single(cfg, q, positions, mrope)
    k = _rope_single(cfg, k, positions, mrope)

    pool_k_l, pool_v_l, scales_l = paged.write_token_kv(
        pool_k_l, pool_v_l, k, v, write_slot, positions, chip, npr,
        page_size, scales=scales_l)

    n_kv, G = cfg.n_kv, cfg.n_q // cfg.n_kv
    if fused:
        # one Pallas dispatch: in-kernel block-table walk + double-buffered
        # page DMA + attention partials (kernels/fused_decode)
        local_bt = _local_block_table(bt, chip, npr)
        o, m, l = fused_decode_kernel(q, pool_k_l, pool_v_l, local_bt,
                                      positions, scales=scales_l,
                                      partials=True, interpret=interpret)
    else:
        lp = paged.LocalPages(*(t[0] for t in lp_tree))
        qg = q.reshape(B, n_kv, G, cfg.hd)
        o, m, l = paged.attend_local(qg, pool_k_l, pool_v_l, lp, positions,
                                     page_size, scales=scales_l)
    out = paged.merge_global(o, m, l, axes_names)    # [B,kv,G,hd] f32
    out = out.reshape(B, cfg.n_q, cfg.hd).astype(x.dtype)

    if axes_names and q_sharded:
        hl = cfg.n_q // mesh.shape["model"]
        my = jax.lax.dynamic_slice_in_dim(
            out, jax.lax.axis_index("model") * hl, hl, axis=1)
        y = jax.lax.psum(L.attn_out_decode(ap, my), "model")
    else:
        y = L.attn_out_decode(ap, out)
    if scales_l is None:
        scales_l = (jnp.zeros((), jnp.bfloat16),) * 2   # dummy pytree
    return y[:, None], pool_k_l, pool_v_l, scales_l


def paged_attn_op(cfg, rules, x, ap, pool_k_l, pool_v_l, lp_arrays,
                  write_slot, positions, mrope=None,
                  page_size: int = DEFAULT_PAGE_SIZE, scales_l=None,
                  bt=None, fused: bool = False, interpret: bool = False):
    """x [B,1,d]; pools [n_pages,...]; lp_arrays: LocalPages as [n_chips,CAP]
    arrays (None when ``fused`` — the kernel walks the raw block table
    ``bt`` int32[B, maxP] instead).  Returns (attn_out [B,1,d], pool_k',
    pool_v', scales')."""
    if rules is None:
        lp_tree = (None if lp_arrays is None
                   else tuple(t[:1] for t in lp_arrays))
        return _paged_attn_chip(
            cfg, x, ap, pool_k_l, pool_v_l, scales_l, lp_tree, write_slot,
            positions, mrope, bt, axes_names=(), mesh=None,
            page_size=page_size, kv_sharded=False, q_sharded=False,
            fused=fused, interpret=interpret)

    mesh = rules.mesh
    axes_names = _mesh_axes(rules)
    tp = mesh.shape.get("model", 1)
    kv_sharded = cfg.n_kv % tp == 0 and tp > 1
    q_sharded = cfg.n_q % tp == 0 and tp > 1
    chips = P(axes_names)
    h_spec = P(None, "model", None) if q_sharded else P()
    kvw_spec = P(None, "model", None) if kv_sharded else P()
    ap_specs = {"wq": h_spec, "wk": kvw_spec, "wv": kvw_spec,
                "wo": P("model", None, None) if q_sharded else P()}
    if "bq" in ap:
        ap_specs.update({
            "bq": P("model", None) if q_sharded else P(),
            "bk": P("model", None) if kv_sharded else P(),
            "bv": P("model", None) if kv_sharded else P()})
    pool_spec = P(axes_names, None, None, None)
    scale_spec = P(axes_names, None, None)
    lp_specs = (None if lp_arrays is None
                else tuple(P(axes_names, None) for _ in lp_arrays))

    fn = functools.partial(
        _paged_attn_chip, cfg, axes_names=axes_names, mesh=mesh,
        page_size=page_size, kv_sharded=kv_sharded, q_sharded=q_sharded,
        fused=fused, interpret=interpret)
    scales_spec = ((scale_spec, scale_spec) if scales_l is not None
                   else None)
    out_scales_spec = (scales_spec if scales_l is not None
                       else (P(), P()))
    mapped = shard_map(
        fn, mesh=mesh,
        in_specs=(P(), ap_specs, pool_spec, pool_spec, scales_spec,
                  lp_specs, P(), P(),
                  P() if mrope is not None else None,
                  P() if bt is not None else None),
        out_specs=(P(), pool_spec, pool_spec, out_scales_spec),
        check_vma=False)
    return mapped(x, ap, pool_k_l, pool_v_l, scales_l, lp_arrays,
                  write_slot, positions, mrope, bt)


def compact_op(rules, slots, n_pages: int, cap: int):
    """Per-chip page compaction, once per serve step.  Returns LocalPages as
    [n_chips, CAP] arrays (chip-sharded when a mesh is active)."""
    if rules is None:
        lp = paged.compact_local(slots, 0, n_pages, cap)
        return tuple(t[None] for t in lp)
    mesh = rules.mesh
    axes_names = _mesh_axes(rules)
    n_chips = _n_chips(rules)
    npr = n_pages // n_chips

    def fn(slots):
        chip = _chip_idx(axes_names, mesh)
        lp = paged.compact_local(slots, chip, npr, cap)
        return tuple(t[None] for t in lp)

    mapped = shard_map(
        fn, mesh=mesh, in_specs=(P(),),
        out_specs=tuple(P(axes_names, None) for _ in range(4)),
        check_vma=False)
    return mapped(slots)


# ---------------------------------------------------------------------------
# Ring-buffer (sliding window) attention for gemma3 local layers.

def _ring_attn(cfg, x, ap, ring_k_l, ring_v_l, ring_pos, positions):
    """x [B,1,d]; ring [B,W,kv,hd]; ring_pos [B,W] absolute positions."""
    B = x.shape[0]
    W = ring_k_l.shape[1]
    q, k, v = L.attn_qkv_decode(ap, x[:, 0])
    q = _rope_single(cfg, q, positions)
    k = _rope_single(cfg, k, positions)
    slot = positions % W
    ring_k_l = ring_k_l.at[jnp.arange(B), slot].set(k.astype(ring_k_l.dtype))
    ring_v_l = ring_v_l.at[jnp.arange(B), slot].set(v.astype(ring_v_l.dtype))

    n_kv, G = cfg.n_kv, cfg.n_q // cfg.n_kv
    qg = q.reshape(B, n_kv, G, cfg.hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg.astype(jnp.float32),
                   ring_k_l.astype(jnp.float32)) / math.sqrt(cfg.hd)
    ok = (ring_pos >= 0) & (ring_pos <= positions[:, None]) & \
        (ring_pos > positions[:, None] - W)
    ok = ok.at[jnp.arange(B), slot].set(True)
    s = jnp.where(ok[:, None, None, :], s, paged.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, ring_v_l.astype(jnp.float32))
    o = o.reshape(B, cfg.n_q, cfg.hd).astype(x.dtype)
    return L.attn_out_decode(ap, o)[:, None], ring_k_l, ring_v_l


# ---------------------------------------------------------------------------
# Cross attention at decode (encdec): dense precomputed memory K/V.

def _cross_attn_decode(cfg, x, cp, ck, cv):
    """x [B,1,d]; ck/cv [B,S_src,kv,hd]."""
    B = x.shape[0]
    q = jnp.einsum("bd,dhk->bhk", x[:, 0], cp["wq"])
    if "bq" in cp:
        q = q + cp["bq"]
    n_kv, G = cfg.n_kv, cfg.n_q // cfg.n_kv
    qg = q.reshape(B, n_kv, G, cfg.hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / math.sqrt(cfg.hd)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, cv.astype(jnp.float32))
    o = o.reshape(B, cfg.n_q, cfg.hd).astype(x.dtype)
    return L.attn_out_decode(cp, o)[:, None]


# ---------------------------------------------------------------------------
# serve_step factories.

def make_serve_step(cfg, *, S_max: int, rules=None,
                    page_size: int = DEFAULT_PAGE_SIZE):
    """Returns serve_step(params, state, tokens [B,1], positions [B],
    [mrope_positions]) -> (logits [B,V], state')."""
    if cfg.fused_kernel and not _fused_kernel_ok(cfg, rules):
        # never a silent fallback: the caller asked for the fused kernel
        logger.warning(
            "fused decode kernel unavailable for %s — %s; "
            "using the two-dispatch attend path",
            cfg.name, _fused_kernel_reason(cfg, rules))
    if _probe_strategy_reason(cfg, rules) is not None:
        # the strategy itself still runs (jnp allocator); only the probe
        # kernel surface degrades — logged, mirrored in fallback_report
        logger.warning(
            "probe strategy %s partially degraded for %s — %s",
            cfg.probe_strategy, cfg.name, _probe_strategy_reason(cfg, rules))
    if rules is not None and _manual_decode_ok(cfg, rules):
        return _make_manual_serve_step(cfg, S_max=S_max, rules=rules,
                                       page_size=page_size)
    if rules is not None and cfg.tp_impl == "manual":
        # never a silent fallback: the caller asked for the fused region
        logger.warning(
            "fused manual-TP decode unavailable for %s — %s; "
            "falling back to the gspmd serve step",
            cfg.name, _manual_decode_reason(cfg, rules))
    n_chips = _n_chips(rules)
    family = cfg.family

    def serve_step(params, state, tokens, positions, mrope_positions=None):
        with ctx.use_rules(rules):
            return _serve_step_impl(cfg, params, state, tokens, positions,
                                    mrope_positions, rules=rules,
                                    S_max=S_max, page_size=page_size,
                                    n_chips=n_chips)

    return serve_step


def make_serve_megastep(cfg, *, S_max: int, K: int, rules=None,
                        page_size: int = DEFAULT_PAGE_SIZE):
    """The decode megastep: K tokens per dispatch via one ``jax.lax.scan``
    over the per-token serve body — in-graph greedy sampling feeds token
    t+1 from token t's logits, page allocation runs inside the scan, and
    done/abort conditions latch into on-device flags, so the host syncs
    once per K tokens instead of once per token.

    Returns ``megastep(params, state, tokens [B,1], stop_len=None,
    forced=None, forced_mask=None) -> (tokens int32[B, K], state')``.
    ``forced``/``forced_mask`` [B, K] teacher-force the fed tokens where the
    mask is set (chunked prefill: a lane consumes up to K prompt tokens per
    dispatch and flips to greedy decode mid-megastep — see ``_mega_scan``),
    so prefill and decode share one dispatch budget.  Positions come from
    ``state["pos"]``
    (the engine is the source of truth); for the vlm family the M-RoPE
    positions are derived in-graph from the same counter.  ``tokens[:, -1]``
    is always the correct next feed: the last greedy sample for healthy
    lanes, the frozen refused token for lanes that ABORTed mid-megastep
    (their ``pos`` did not advance — after ``rebuild_page_table`` the next
    megastep re-issues the refused suffix automatically).  ``stop_len``
    int32[B] latches ``active=False`` in-graph when a lane's position
    reaches its stop, so finished lanes stop allocating pages without a
    host round-trip.  K=1 degenerates to the single step + in-graph argmax.

    With ``tp_impl="manual"`` the whole scan lives inside the single
    fully-manual shard_map region; otherwise the per-token body is the
    gspmd step.  The factory tags the returned fn with ``.megastep``
    (``"scan-K{K}"``) — recorded by dry-run artifacts so a silent fallback
    to per-token dispatch fails CI's ``--expect-fused``."""
    if cfg.fused_kernel and not _fused_kernel_ok(cfg, rules):
        logger.warning(
            "fused decode kernel unavailable for %s — %s; "
            "using the two-dispatch attend path",
            cfg.name, _fused_kernel_reason(cfg, rules))
    if _probe_strategy_reason(cfg, rules) is not None:
        logger.warning(
            "probe strategy %s partially degraded for %s — %s",
            cfg.probe_strategy, cfg.name, _probe_strategy_reason(cfg, rules))
    if rules is not None and _manual_decode_ok(cfg, rules):
        return _make_manual_serve_megastep(cfg, S_max=S_max, K=K,
                                           rules=rules, page_size=page_size)
    if rules is not None and cfg.tp_impl == "manual":
        logger.warning(
            "fused manual-TP decode unavailable for %s — %s; "
            "megastep runs over the gspmd serve body",
            cfg.name, _manual_decode_reason(cfg, rules))
    n_chips = _n_chips(rules)

    def megastep(params, state, tokens, stop_len=None, forced=None,
                 forced_mask=None):
        def token_step(st, tok, pos, mrope):
            with ctx.use_rules(rules):
                return _serve_step_impl(cfg, params, st, tok, pos, mrope,
                                        rules=rules, S_max=S_max,
                                        page_size=page_size,
                                        n_chips=n_chips)
        return _mega_scan(cfg, K, token_step, state, tokens, stop_len,
                          forced, forced_mask)

    megastep.megastep = TP.decode_megastep_mode(cfg, rules, K)
    return megastep


# ---------------------------------------------------------------------------
# Fused manual-TP decode (tp_impl="manual"): the whole step in ONE manual
# shard_map region over every mesh axis.

def _qkv_decode_shard(ap, x, kv_rep: int):
    """Per-chip decode QKV inside the fused manual region.  ``kv_rep == 1``:
    the K/V weights were head-sharded by the enclosing shard_map and the
    projection is already local.  ``kv_rep > 1`` (model axis wider than
    n_kv): the K/V weights arrive REPLICATED — compute the full [B, n_kv,
    hd] K/V and keep this chip's single replicated head."""
    q, k, v = L.attn_qkv_decode(ap, x)
    k, v = L.kv_head_slice(k, v, jax.lax.axis_index("model"), kv_rep)
    return q, k, v


def _paged_attn_shard(cfg, x, ap, pk, pv, scales, lp, write_slot, positions,
                      mrope, *, chip_pd, npr, page_size, pd_axes,
                      kv_rep=1, fused_bt=None, interpret=False):
    """One attention sublayer inside the fused manual region, local head
    shard end-to-end: column-parallel QKV, KV write into the chip's own
    pages, per-chip paged attention over local (page, head) slices, lse
    merge across the page axes only, row-parallel out + one psum.  With
    ``kv_rep > 1`` each chip holds ONE replicated KV head serving its
    (disjoint) slice of that head's query group — the psum over ``model``
    still sums distinct q-head contributions exactly once."""
    B = x.shape[0]
    q, k, v = _qkv_decode_shard(ap, x[:, 0], kv_rep)
    q = _rope_single(cfg, q, positions, mrope)
    k = _rope_single(cfg, k, positions, mrope)
    pk, pv, scales = paged.write_token_kv(pk, pv, k, v, write_slot,
                                          positions, chip_pd, npr,
                                          page_size, scales=scales)
    kv_l = k.shape[1]                              # n_kv·rep / tp
    G_l = q.shape[1] // kv_l                       # local group size
    if fused_bt is not None:
        # one Pallas dispatch per layer: in-kernel walk of the chip-local
        # raw block table + double-buffered page DMA (kernels/fused_decode);
        # same (o, m, l) partials contract as paged.attend_local
        o, m, l = fused_decode_kernel(q, pk, pv, fused_bt, positions,
                                      scales=scales, partials=True,
                                      interpret=interpret)
    else:
        qg = q.reshape(B, kv_l, G_l, cfg.hd)       # grouping is head-local
        o, m, l = paged.attend_local(qg, pk, pv, lp, positions, page_size,
                                     scales=scales)
    out = paged.merge_global(o, m, l, pd_axes)     # heads never cross chips
    out = out.reshape(B, kv_l * G_l, cfg.hd).astype(x.dtype)
    y = jax.lax.psum(L.attn_out_decode(ap, out), "model")
    if scales is None:
        scales = (jnp.zeros((), jnp.bfloat16),) * 2   # dummy pytree
    return y[:, None], pk, pv, scales


def _ring_attn_shard(cfg, x, ap, ring_k_l, ring_v_l, ring_pos, positions,
                     kv_rep=1):
    """gemma3 local-window layer inside the fused manual region: the ring
    buffer is head-sharded over ``model`` (same tiled-head layout as the
    pools), each chip attends its own q-head slice against its resident KV
    head's full window — the softmax needs no cross-chip merge — then
    row-parallel out + one psum.  x [B,1,d]; ring_*_l [B,W,kv_l,hd]."""
    B = x.shape[0]
    W = ring_k_l.shape[1]
    q, k, v = _qkv_decode_shard(ap, x[:, 0], kv_rep)
    q = _rope_single(cfg, q, positions)
    k = _rope_single(cfg, k, positions)
    slot = positions % W
    ring_k_l = ring_k_l.at[jnp.arange(B), slot].set(k.astype(ring_k_l.dtype))
    ring_v_l = ring_v_l.at[jnp.arange(B), slot].set(v.astype(ring_v_l.dtype))

    kv_l = k.shape[1]
    G_l = q.shape[1] // kv_l
    qg = q.reshape(B, kv_l, G_l, cfg.hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg.astype(jnp.float32),
                   ring_k_l.astype(jnp.float32)) / math.sqrt(cfg.hd)
    ok = (ring_pos >= 0) & (ring_pos <= positions[:, None]) & \
        (ring_pos > positions[:, None] - W)
    ok = ok.at[jnp.arange(B), slot].set(True)
    s = jnp.where(ok[:, None, None, :], s, paged.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, ring_v_l.astype(jnp.float32))
    o = o.reshape(B, kv_l * G_l, cfg.hd).astype(x.dtype)
    y = jax.lax.psum(L.attn_out_decode(ap, o), "model")
    return y[:, None], ring_k_l, ring_v_l


def _manual_decode_parts(cfg, *, S_max: int, rules,
                         page_size: int = DEFAULT_PAGE_SIZE):
    """Shared pieces of the fused manual-TP decode region: the shard_map
    spec builder and the per-token body (runs INSIDE the region) — used by
    both the single serve step and the K-token megastep, which wraps the
    same body in an in-region ``lax.scan``."""
    mesh = rules.mesh
    pd_axes = _pd_axes(rules)
    n_pd = 1
    for a in pd_axes:
        n_pd *= mesh.shape[a]
    tp = mesh.shape["model"]
    kv_rep = TP.decode_kv_rep(cfg, tp)
    ssm_tp = cfg.family == "hybrid" and TP.decode_ssm_tp(cfg, tp)
    maxP = -(-S_max // page_size)
    vocab_sharded = (not cfg.tie_embeddings) and cfg.vocab_size % tp == 0
    use_fused = _fused_kernel_ok(cfg, rules)
    interp = _kernel_interpret()

    def make_specs(params, state):
        pool_spec = P(None, pd_axes or None, None, "model", None)
        state_specs: Dict[str, Any] = {k: P() for k in state}
        state_specs["pools"] = paged.PagedPools(k=pool_spec, v=pool_spec)
        if "pool_scales" in state:
            sc = P(None, pd_axes or None, None, "model")
            state_specs["pool_scales"] = paged.PoolScales(k=sc, v=sc)
        if "ring_k" in state:
            ring_spec = P(None, None, None, "model", None)
            state_specs["ring_k"] = ring_spec
            state_specs["ring_v"] = ring_spec
        if ssm_tp and "ssm" in state:
            # mamba state head-sharded over model (ssm_heads / ssm_inner
            # rules): h [L,B,G,Hg,P,N] on Hg, conv_x [L,B,W-1,di] on di;
            # the shared B/C conv tail stays replicated
            state_specs["ssm"] = ssm.MambaState(
                h=P(None, None, None, "model", None, None),
                conv_x=P(None, None, None, "model"),
                conv_bc=P())
        param_specs = TP.decode_param_specs(cfg, params,
                                            vocab_sharded=vocab_sharded,
                                            kv_rep=kv_rep, ssm_tp=ssm_tp)
        return param_specs, state_specs

    def token_body(params, state, tokens, positions, mrope, *, npr, cap):
        x = nn.embed_lookup(params["embed"], tokens)      # replicated
        new_state = dict(state)
        chip_pd = _chip_idx(pd_axes, mesh)
        act = state["active"] & ~state["aborted"]
        # once per token, identical on every chip: incremental allocation
        # (only crossings probe) + the cached block-table read; the paper's
        # wait-free lookup stays authoritative for admission/rebuild
        (table, write_slot, aborts), bt = _pt(cfg).alloc_step_incremental(
            state["table"], state["seq_ids"], positions,
            state["block_table"], page_size=page_size, active=act)
        if use_fused:
            # the fused kernel walks the raw block table in-kernel: no
            # materialized slots view, no per-chip compaction pass
            lp, fused_bt = None, _local_block_table(bt, chip_pd, npr)
        else:
            slots = PT.PageTable.block_table_slots(
                bt, positions, page_size=page_size)
            lp, fused_bt = paged.compact_local(slots, chip_pd, npr, cap), None
        new_state["table"] = table
        new_state["block_table"] = bt
        new_state["aborted"] = state["aborted"] | aborts
        if "counters" in state:
            # replicated scalar adds, identical on every chip — the counter
            # plane crosses to the host only at the per-K megastep sync
            new_state["counters"] = OC.update_token_counters(
                state["counters"], act=act, aborts=aborts,
                positions=positions, page_size=page_size,
                table_before=state["table"], table_after=table)

        attn = functools.partial(
            _paged_attn_shard, cfg, lp=lp, write_slot=write_slot,
            positions=positions, chip_pd=chip_pd, npr=npr,
            page_size=page_size, pd_axes=pd_axes, kv_rep=kv_rep,
            fused_bt=fused_bt, interpret=interp)

        if cfg.pattern_local:
            x_out = _gemma_layers_shard(cfg, params, state, new_state,
                                        x, attn, positions, kv_rep)
        elif cfg.family == "hybrid":
            x_out = _hybrid_layers_shard(cfg, params, state, new_state,
                                         x, attn,
                                         ssm_axis="model" if ssm_tp
                                         else None)
        else:
            sk, sv = _scale_xs(cfg, state, cfg.num_layers)

            def layer(x, xs):
                lpar, pk, pv, sk_l, sv_l = xs
                h, pk, pv, sc = attn(
                    nn.rmsnorm(lpar["ln1"], x), lpar["attn"], pk, pv,
                    _scales_in(cfg, sk_l, sv_l), mrope=mrope)
                x = x + h
                xn = nn.rmsnorm(lpar["ln2"], x)
                if cfg.family == "moe":
                    y = MOE.moe_decode_local(lpar["moe"], xn, cfg)
                else:
                    y = TP.mlp_decode_manual(lpar["mlp"], xn)
                return x + y, (pk, pv) + tuple(sc)

            x_out, (pk, pv, sk2, sv2) = jax.lax.scan(
                layer, x, (params["layers"], state["pools"].k,
                           state["pools"].v, sk, sv),
                unroll=cfg.scan_unroll)
            new_state["pools"] = paged.PagedPools(k=pk, v=pv)
            if cfg.kv_cache_dtype == "int8":
                new_state["pool_scales"] = paged.PoolScales(k=sk2,
                                                            v=sv2)
        x_out = nn.rmsnorm(params["final_norm"], x_out)
        logits = TP.logits_decode_manual(cfg, params, x_out,
                                         vocab_sharded=vocab_sharded)
        new_state["pos"] = jnp.where(act & ~aborts, positions + 1,
                                     positions)
        return logits[:, 0].astype(jnp.float32), new_state

    return mesh, n_pd, maxP, make_specs, token_body


def _make_manual_serve_step(cfg, *, S_max: int, rules,
                            page_size: int = DEFAULT_PAGE_SIZE):
    """Decode step for ``tp_impl="manual"``: page-table alloc + block-table
    read + compaction + all layers + read-out fused into a single manual
    shard_map (see module docstring for the layout).  Covers the dense /
    moe / vlm stacked scan, the gemma3 local:global superblocks (ring
    buffers head-sharded in-region) and the hybrid mamba backbone + shared
    attention block (mamba replicated, shared block Megatron-sharded)."""
    mesh, n_pd, maxP, make_specs, token_body = _manual_decode_parts(
        cfg, S_max=S_max, rules=rules, page_size=page_size)

    def serve_step(params, state, tokens, positions, mrope_positions=None):
        B = tokens.shape[0]
        n_pages = state["pools"].k.shape[1]
        npr = n_pages // n_pd
        cap = paged.capacity(B, maxP, n_pd,
                             factor=cfg.page_capacity_factor)
        param_specs, state_specs = make_specs(params, state)
        mr_spec = P() if mrope_positions is not None else None

        def body(params, state, tokens, positions, mrope):
            return token_body(params, state, tokens, positions, mrope,
                              npr=npr, cap=cap)

        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(param_specs, state_specs, P(), P(), mr_spec),
            out_specs=(P(), state_specs), check_vma=False)
        return mapped(params, state, tokens, positions, mrope_positions)

    return serve_step


def _mega_scan(cfg, K: int, token_step, state, tokens, stop_len,
               forced=None, forced_mask=None):
    """The K-token scan at the megastep's core: in-graph greedy sampling
    feeds token t+1 from token t's logits; a lane whose allocation ABORTs
    latches — its pending (refused) token and position freeze so the host
    can re-issue the suffix after a rebuild; with ``stop_len`` a lane whose
    position reaches its stop latches ``active=False`` (done) in-graph.
    Returns (tokens int32[B, K] — entry k is the token sampled after step k,
    frozen at the refused token for aborted lanes — and the final state).

    CHUNKED PREFILL (``forced``/``forced_mask`` int32/bool[B, K]): where
    ``forced_mask[:, k]`` is True, the token FED at scan step k+1 is
    ``forced[:, k]`` instead of the greedy sample — a prefilling lane
    consumes up to K prompt tokens per dispatch (its KV is written exactly
    as in teacher forcing) and transitions to greedy decode mid-megastep
    the moment its mask runs out, so prefill and decode share one dispatch
    budget.  Column K-1 overrides the RETURNED pending feed ``toks[:, -1]``
    (the next round's first token).  The abort latch wins over forcing: a
    refused forced token stays pending for the post-rebuild re-issue."""
    B = tokens.shape[0]

    def one(carry, xs):
        st, tok = carry
        pos = st["pos"]
        mrope = (jnp.broadcast_to(pos[None, :, None],
                                  (3, B, 1)).astype(jnp.int32)
                 if cfg.family == "vlm" else None)
        logits, st2 = token_step(st, tok, pos, mrope)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if xs is not None:
            f_tok, f_msk = xs
            nxt = jnp.where(f_msk[:, None], f_tok[:, None], nxt)
        # aborted lanes keep their refused token pending for the re-issue
        tok2 = jnp.where(st2["aborted"][:, None], tok, nxt)
        if stop_len is not None:
            st2 = dict(st2)
            st2["active"] = st2["active"] & (st2["pos"] < stop_len)
        return (st2, tok2), tok2[:, 0]

    xs = None
    if forced is not None:
        xs = (jnp.asarray(forced, jnp.int32).T,
              jnp.asarray(forced_mask, bool).T)       # [K, B] scan inputs
    (st, _), toks = jax.lax.scan(one, (state, tokens), xs, length=K)
    return toks.T, st


def _make_manual_serve_megastep(cfg, *, S_max: int, K: int, rules,
                                page_size: int = DEFAULT_PAGE_SIZE):
    """Megastep twin of ``_make_manual_serve_step``: the K-token scan lives
    INSIDE the single fully-manual shard_map region (the pinned XLA rejects
    partially-auto regions — dist/README), so K tokens cost one dispatch
    and zero host round-trips."""
    mesh, n_pd, maxP, make_specs, token_body = _manual_decode_parts(
        cfg, S_max=S_max, rules=rules, page_size=page_size)

    def megastep(params, state, tokens, stop_len=None, forced=None,
                 forced_mask=None):
        B = tokens.shape[0]
        n_pages = state["pools"].k.shape[1]
        npr = n_pages // n_pd
        cap = paged.capacity(B, maxP, n_pd,
                             factor=cfg.page_capacity_factor)
        param_specs, state_specs = make_specs(params, state)
        stop_spec = P() if stop_len is not None else None
        f_spec = P() if forced is not None else None

        def body(params, state, tokens, stop_len, forced, forced_mask):
            def token_step(st, tok, pos, mrope):
                return token_body(params, st, tok, pos, mrope,
                                  npr=npr, cap=cap)
            return _mega_scan(cfg, K, token_step, state, tokens, stop_len,
                              forced, forced_mask)

        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(param_specs, state_specs, P(), stop_spec, f_spec,
                      f_spec),
            out_specs=(P(), state_specs), check_vma=False)
        return mapped(params, state, tokens, stop_len, forced, forced_mask)

    megastep.megastep = TP.decode_megastep_mode(cfg, rules, K)
    return megastep


def _gemma_layers_shard(cfg, params, state, new_state, x, attn, positions,
                        kv_rep):
    """gemma3 superblocks inside the fused manual region: ``pattern_local``
    ring layers (head-sharded window attention) + 1 paged global layer per
    group — the manual twin of ``_gemma_layers``."""
    pat = cfg.pattern_local
    group = pat + 1
    ng = cfg.num_layers // group
    stacked = jax.tree.map(
        lambda t: t.reshape((ng, group) + t.shape[1:]), params["layers"])
    B, W = state["ring_pos"].shape
    ring_k = state["ring_k"].reshape((ng, pat) + state["ring_k"].shape[1:])
    ring_v = state["ring_v"].reshape((ng, pat) + state["ring_v"].shape[1:])
    sk, sv = _scale_xs(cfg, state, ng)

    def body(x, xs):
        grp, rks, rvs, pk, pv, sk_l, sv_l = xs
        new_rk, new_rv = [], []
        for i in range(pat):
            sub = jax.tree.map(lambda t: t[i], grp)
            h, rk2, rv2 = _ring_attn_shard(
                cfg, nn.rmsnorm(sub["ln1"], x), sub["attn"], rks[i],
                rvs[i], state["ring_pos"], positions, kv_rep)
            x = x + h
            x = x + TP.mlp_decode_manual(sub["mlp"],
                                         nn.rmsnorm(sub["ln2"], x))
            new_rk.append(rk2)
            new_rv.append(rv2)
        sub = jax.tree.map(lambda t: t[pat], grp)
        h, pk, pv, sc = attn(nn.rmsnorm(sub["ln1"], x), sub["attn"], pk,
                             pv, _scales_in(cfg, sk_l, sv_l), mrope=None)
        x = x + h
        x = x + TP.mlp_decode_manual(sub["mlp"], nn.rmsnorm(sub["ln2"], x))
        return x, (jnp.stack(new_rk), jnp.stack(new_rv), pk, pv) + tuple(sc)

    x, (rk, rv, pk, pv, sk2, sv2) = jax.lax.scan(
        body, x, (stacked, ring_k, ring_v, state["pools"].k,
                  state["pools"].v, sk, sv),
        unroll=ng if cfg.unroll_layers else 1)
    new_state["ring_k"] = rk.reshape((ng * pat,) + rk.shape[2:])
    new_state["ring_v"] = rv.reshape((ng * pat,) + rv.shape[2:])
    new_state["ring_pos"] = state["ring_pos"].at[
        jnp.arange(B), positions % W].set(positions)
    new_state["pools"] = paged.PagedPools(k=pk, v=pv)
    if cfg.kv_cache_dtype == "int8":
        new_state["pool_scales"] = paged.PoolScales(k=sk2, v=sv2)
    return x


def _hybrid_layers_shard(cfg, params, state, new_state, x, attn,
                         ssm_axis=None):
    """zamba2 hybrid inside the fused manual region: the ONE shared
    attention + MLP block is Megatron-sharded with per-invocation paged KV;
    the Mamba backbone shards its per-head inner dims over ``model``
    (``ssm_axis="model"`` when ``dist/tp.decode_ssm_tp`` passes — params
    and recurrent state arrive head-sharded, ``mamba_decode_step`` psums
    the RMS statistic and the row-parallel out projection) and runs as
    replicated redundant compute otherwise."""
    every = cfg.shared_attn_every
    n_inv = cfg.num_layers // every
    sp = params["shared"]
    pk, pv = state["pools"].k, state["pools"].v
    sk, sv = _scale_xs(cfg, state, n_inv)
    new_ssm_chunks = []
    pk_out, pv_out, sk_out, sv_out = [], [], [], []
    for g in range(n_inv):
        x, s2 = HY.mamba_decode_chunk(cfg, params["layers"], state["ssm"],
                                      x, g * every, (g + 1) * every,
                                      tp_axis=ssm_axis)
        new_ssm_chunks.append(s2)
        h, pk_g, pv_g, sc = attn(nn.rmsnorm(sp["ln1"], x), sp["attn"],
                                 pk[g], pv[g],
                                 _scales_in(cfg, sk[g], sv[g]), mrope=None)
        x = x + h
        x = x + TP.mlp_decode_manual(sp["mlp"], nn.rmsnorm(sp["ln2"], x))
        pk_out.append(pk_g)
        pv_out.append(pv_g)
        sk_out.append(sc[0])
        sv_out.append(sc[1])
    rem = cfg.num_layers - n_inv * every
    if rem:
        x, s2 = HY.mamba_decode_chunk(cfg, params["layers"], state["ssm"],
                                      x, n_inv * every, cfg.num_layers,
                                      tp_axis=ssm_axis)
        new_ssm_chunks.append(s2)
    # new_state["aborted"] already includes this step's aborts: a refused
    # lane's recurrence must not advance (its token is re-issued later)
    new_state["ssm"] = _freeze_lanes(
        jax.tree.map(lambda *ts: jnp.concatenate(ts, axis=0),
                     *new_ssm_chunks),
        state["ssm"], state["active"] & ~new_state["aborted"])
    new_state["pools"] = paged.PagedPools(k=jnp.stack(pk_out),
                                          v=jnp.stack(pv_out))
    if cfg.kv_cache_dtype == "int8":
        new_state["pool_scales"] = paged.PoolScales(k=jnp.stack(sk_out),
                                                    v=jnp.stack(sv_out))
    return x


def _page_ops(cfg, state, positions, active, *, S_max, page_size, n_chips,
              rules, fused=False):
    """Once-per-token page-table work: incremental allocation (only the
    page-boundary crossings probe the table) + the block-table read served
    from the persistent cache — O(crossings) probes instead of the
    O(B·max_pages) full re-probe (``PageTable.lookup_pages`` stays the
    authoritative path for admission / rebuild / verification).  With
    ``fused`` the slots view + per-chip compaction are skipped entirely:
    the fused kernel walks the raw block table in-kernel."""
    maxP = -(-S_max // page_size)
    (table, write_slot, aborts), bt = _pt(cfg).alloc_step_incremental(
        state["table"], state["seq_ids"], positions, state["block_table"],
        page_size=page_size, active=active)
    if fused:
        return table, write_slot, aborts, bt, None
    slots = PT.PageTable.block_table_slots(bt, positions,
                                           page_size=page_size)
    B = positions.shape[0]
    cap = paged.capacity(B, maxP, n_chips,
                         factor=cfg.page_capacity_factor)
    lp_arrays = compact_op(rules, slots, BT.size(table), cap)
    return table, write_slot, aborts, bt, lp_arrays


def _freeze_lanes(new_tree, old_tree, act):
    """Per-lane state freeze for refused/inactive lanes: leaves are
    [L, B, ...] stacked per-layer state.  A refused token must be
    side-effect-free — SSM recurrences are NOT idempotent under re-issue
    (unlike the KV/ring writes, which rewrite the same slot with the same
    value), so the engine masks them here."""
    def sel(n, o):
        m = act.reshape((1, -1) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new_tree, old_tree)


def _scale_xs(cfg, state, n_layers):
    """Per-layer scale arrays for the scan xs (dummies when bf16 pools)."""
    if cfg.kv_cache_dtype == "int8":
        sc = state["pool_scales"]
        return sc.k, sc.v
    z = jnp.zeros((n_layers,), jnp.bfloat16)
    return z, z


def _scales_in(cfg, sk_l, sv_l):
    return (sk_l, sv_l) if cfg.kv_cache_dtype == "int8" else None


def _mlp_or_moe(cfg, p, x):
    if cfg.family == "moe":
        y, _ = MOE.moe_apply(p["moe"], x, cfg)
        return y
    return L.mlp_apply(p["mlp"], x)


def _serve_step_impl(cfg, params, state, tokens, positions, mrope,
                     *, rules, S_max, page_size, n_chips):
    B = tokens.shape[0]
    x = nn.embed_lookup(params["embed"], tokens)      # [B,1,d]
    new_state = dict(state)
    act = state["active"] & ~state["aborted"]
    aborts = jnp.zeros((B,), bool)
    fused = _fused_kernel_ok(cfg, rules)
    interp = _kernel_interpret()

    if cfg.family in ("dense", "moe", "vlm"):
        table, write_slot, aborts, bt, lp = _page_ops(
            cfg, state, positions, act, S_max=S_max, page_size=page_size,
            n_chips=n_chips, rules=rules, fused=fused)
        new_state["table"] = table
        new_state["block_table"] = bt

        if cfg.pattern_local:
            x, pools, ring, scales = _gemma_layers(cfg, params, state, x,
                                                   lp, write_slot,
                                                   positions, rules,
                                                   page_size, bt=bt,
                                                   fused=fused,
                                                   interpret=interp)
            new_state["pools"] = pools
            new_state["ring_k"], new_state["ring_v"], new_state["ring_pos"] \
                = ring
            if scales is not None:
                new_state["pool_scales"] = scales
        else:
            sk, sv = _scale_xs(cfg, state, cfg.num_layers)

            def body(x, xs):
                lp_params, pk, pv, sk_l, sv_l = xs
                h, pk, pv, sc = paged_attn_op(
                    cfg, rules, nn.rmsnorm(lp_params["ln1"], x), lp_params["attn"],
                    pk, pv, lp, write_slot, positions, mrope, page_size,
                    scales_l=_scales_in(cfg, sk_l, sv_l),
                    bt=bt if fused else None, fused=fused, interpret=interp)
                x = x + h
                x = x + _mlp_or_moe(cfg, lp_params,
                                    nn.rmsnorm(lp_params["ln2"], x))
                return x, (pk, pv) + tuple(sc)

            x, (pk, pv, sk2, sv2) = jax.lax.scan(
                body, x, (params["layers"], state["pools"].k,
                          state["pools"].v, sk, sv),
                unroll=cfg.scan_unroll)
            new_state["pools"] = paged.PagedPools(k=pk, v=pv)
            if cfg.kv_cache_dtype == "int8":
                new_state["pool_scales"] = paged.PoolScales(k=sk2, v=sv2)

    elif cfg.family == "ssm":
        def body(x, xs):
            lp_params, st = xs
            h, st2 = ssm.mamba_decode_step(
                lp_params["mamba"], nn.rmsnorm(lp_params["ln"], x), cfg, st)
            return x + h, st2

        x, ssm2 = jax.lax.scan(body, x, (params["layers"], state["ssm"]),
                               unroll=cfg.scan_unroll)
        new_state["ssm"] = _freeze_lanes(ssm2, state["ssm"], act)

    elif cfg.family == "hybrid":
        table, write_slot, aborts, bt, lp = _page_ops(
            cfg, state, positions, act, S_max=S_max, page_size=page_size,
            n_chips=n_chips, rules=rules, fused=fused)
        new_state["table"] = table
        new_state["block_table"] = bt
        every = cfg.shared_attn_every
        n_inv = cfg.num_layers // every

        new_ssm_chunks = []
        pk, pv = state["pools"].k, state["pools"].v
        sk, sv = _scale_xs(cfg, state, n_inv)
        pk_out, pv_out, sk_out, sv_out = [], [], [], []
        sp = params["shared"]
        for g in range(n_inv):
            x, s2 = HY.mamba_decode_chunk(cfg, params["layers"],
                                          state["ssm"], x,
                                          g * every, (g + 1) * every)
            new_ssm_chunks.append(s2)
            h, pk_g, pv_g, sc = paged_attn_op(
                cfg, rules, nn.rmsnorm(sp["ln1"], x), sp["attn"],
                pk[g], pv[g], lp, write_slot, positions, None, page_size,
                scales_l=_scales_in(cfg, sk[g], sv[g]),
                bt=bt if fused else None, fused=fused, interpret=interp)
            x = x + h
            x = x + L.mlp_apply(sp["mlp"], nn.rmsnorm(sp["ln2"], x))
            pk_out.append(pk_g)
            pv_out.append(pv_g)
            sk_out.append(sc[0])
            sv_out.append(sc[1])
        rem = cfg.num_layers - n_inv * every
        if rem:
            x, s2 = HY.mamba_decode_chunk(cfg, params["layers"],
                                          state["ssm"], x,
                                          n_inv * every, cfg.num_layers)
            new_ssm_chunks.append(s2)
        # a lane refused THIS step (abort) re-issues its token after the
        # rebuild — its recurrent state must not advance either
        new_state["ssm"] = _freeze_lanes(
            jax.tree.map(lambda *ts: jnp.concatenate(ts, axis=0),
                         *new_ssm_chunks), state["ssm"], act & ~aborts)
        new_state["pools"] = paged.PagedPools(k=jnp.stack(pk_out),
                                              v=jnp.stack(pv_out))
        if cfg.kv_cache_dtype == "int8":
            new_state["pool_scales"] = paged.PoolScales(
                k=jnp.stack(sk_out), v=jnp.stack(sv_out))

    elif cfg.family == "encdec":
        table, write_slot, aborts, bt, lp = _page_ops(
            cfg, state, positions, act, S_max=S_max, page_size=page_size,
            n_chips=n_chips, rules=rules)
        new_state["table"] = table
        new_state["block_table"] = bt

        sk, sv = _scale_xs(cfg, state, cfg.num_layers)

        def body(x, xs):
            lp_params, pk, pv, sk_l, sv_l, ck, cv = xs
            h, pk, pv, sc = paged_attn_op(
                cfg, rules, nn.rmsnorm(lp_params["ln1"], x),
                lp_params["attn"], pk, pv, lp, write_slot, positions, None,
                page_size, scales_l=_scales_in(cfg, sk_l, sv_l))
            x = x + h
            x = x + _cross_attn_decode(cfg, nn.rmsnorm(lp_params["ln_cross"], x),
                                       lp_params["cross"], ck, cv)
            x = x + L.mlp_apply(lp_params["mlp"],
                                nn.rmsnorm(lp_params["ln2"], x))
            return x, (pk, pv) + tuple(sc)

        x, (pk, pv, sk2, sv2) = jax.lax.scan(
            body, x, (params["decoder"], state["pools"].k, state["pools"].v,
                      sk, sv, state["cross_k"], state["cross_v"]),
            unroll=cfg.scan_unroll)
        new_state["pools"] = paged.PagedPools(k=pk, v=pv)
        if cfg.kv_cache_dtype == "int8":
            new_state["pool_scales"] = paged.PoolScales(k=sk2, v=sv2)
    else:
        raise ValueError(cfg.family)

    x = nn.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = nn.embed_logits(params["embed"], x)
    else:
        logits = nn.dense(params["lm_head"], x)
    # inactive lanes stay frozen; aborted lanes refuse the token (pos not
    # advanced, no KV written — the caller must evict or rebuild)
    new_state["aborted"] = state["aborted"] | aborts
    new_state["pos"] = jnp.where(act & ~aborts, positions + 1, positions)
    if "counters" in state:
        # one site covers every family: paged families routed through
        # _page_ops (table deltas + probe twin), ssm has no table leaf so
        # only token/abort counts tick
        new_state["counters"] = OC.update_token_counters(
            state["counters"], act=act, aborts=aborts, positions=positions,
            page_size=page_size, table_before=state.get("table"),
            table_after=new_state.get("table"))
    return logits[:, 0].astype(jnp.float32), new_state


def prepare_encdec_state(cfg, params, state, src_embeds, *, rules=None):
    """Run the encoder and fill the decoder's cross K/V (the enc-dec
    'prefill').  src_embeds [B, S_src, d] (stub audio frontend)."""
    from repro.models import encdec
    with ctx.use_rules(rules):
        memory = encdec.encode(cfg, params, src_embeds)

        def one_layer(lp_params):
            cp = lp_params["cross"]
            k = jnp.einsum("bsd,dhk->bshk", memory, cp["wk"])
            v = jnp.einsum("bsd,dhk->bshk", memory, cp["wv"])
            if "bk" in cp:
                k, v = k + cp["bk"], v + cp["bv"]
            return k, v

        ck, cv = jax.vmap(one_layer)(params["decoder"])
    state = dict(state)
    state["cross_k"], state["cross_v"] = ck, cv
    return state


def _gemma_layers(cfg, params, state, x, lp, write_slot, positions, rules,
                  page_size, bt=None, fused=False, interpret=False):
    """gemma3 superblocks at decode: pattern_local ring layers + 1 paged."""
    pat = cfg.pattern_local
    group = pat + 1
    ng = cfg.num_layers // group
    stacked = jax.tree.map(
        lambda t: t.reshape((ng, group) + t.shape[1:]), params["layers"])
    B, W = state["ring_pos"].shape
    ring_k = state["ring_k"].reshape((ng, pat) + state["ring_k"].shape[1:])
    ring_v = state["ring_v"].reshape((ng, pat) + state["ring_v"].shape[1:])

    sk, sv = _scale_xs(cfg, state, ng)

    def body(x, xs):
        grp, rks, rvs, pk, pv, sk_l, sv_l = xs
        new_rk, new_rv = [], []
        for i in range(pat):
            sub = jax.tree.map(lambda t: t[i], grp)
            h, rk2, rv2 = _ring_attn(cfg, nn.rmsnorm(sub["ln1"], x),
                                     sub["attn"], rks[i], rvs[i],
                                     state["ring_pos"], positions)
            x = x + h
            x = x + L.mlp_apply(sub["mlp"], nn.rmsnorm(sub["ln2"], x))
            new_rk.append(rk2)
            new_rv.append(rv2)
        sub = jax.tree.map(lambda t: t[pat], grp)
        h, pk, pv, sc = paged_attn_op(cfg, rules, nn.rmsnorm(sub["ln1"], x),
                                      sub["attn"], pk, pv, lp, write_slot,
                                      positions, None, page_size,
                                      scales_l=_scales_in(cfg, sk_l, sv_l),
                                      bt=bt if fused else None, fused=fused,
                                      interpret=interpret)
        x = x + h
        x = x + L.mlp_apply(sub["mlp"], nn.rmsnorm(sub["ln2"], x))
        return x, (jnp.stack(new_rk), jnp.stack(new_rv), pk, pv) + tuple(sc)

    x, (rk, rv, pk, pv, sk2, sv2) = jax.lax.scan(
        body, x, (stacked, ring_k, ring_v, state["pools"].k,
                  state["pools"].v, sk, sv),
        unroll=ng if cfg.unroll_layers else 1)
    rk = rk.reshape((ng * pat,) + rk.shape[2:])
    rv = rv.reshape((ng * pat,) + rv.shape[2:])
    ring_pos = state["ring_pos"].at[jnp.arange(B), positions % W].set(
        positions)
    scales = (paged.PoolScales(k=sk2, v=sv2)
              if cfg.kv_cache_dtype == "int8" else None)
    return x, paged.PagedPools(k=pk, v=pv), (rk, rv, ring_pos), scales
