"""Paged flash-decoding attention over the hash-table page pool.

Layout: the physical page pool [n_pages, page_size, n_kv, hd] is sharded on
the page dim across ALL mesh axes (pod·data·model chips), so each chip holds
``npr = n_pages / n_chips`` pages.  The hash allocator (serving/page_table)
spreads a sequence's pages ~uniformly over chips, so per-decode-step KV
bandwidth per chip ≈ total-KV / n_chips — the flash-decoding ideal — and the
"block table" consulted every step is the paper's wait-free lookup.

Per chip, pages of *all* sequences are compacted into one [CAP] list (jointly
over (seq, page) — per-seq capacity would waste ~8x gather bandwidth at high
chip counts), attended against their owning sequence's query, then merged:
log-sum-exp scatter within the chip, lse-weighted psum across chips.

All functions here execute INSIDE shard_map (or standalone when mesh=None —
the single-chip oracle used by tests).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class PagedPools(NamedTuple):
    k: jnp.ndarray   # [L, n_pages, page_size, n_kv, hd]
    v: jnp.ndarray


class PoolScales(NamedTuple):
    """Per-(page, token, head) dequant scales for int8 KV pools (§Perf:
    KIVI-style per-token quantization — 2x pool bandwidth and memory for
    <1% logits error; scales are hd-times smaller than the pools)."""
    k: jnp.ndarray   # bf16 [L, n_pages, page_size, n_kv]
    v: jnp.ndarray


def round_pages(n: int, n_chips: int) -> int:
    return max(1, -(-n // n_chips)) * n_chips


def make_pools(num_layers: int, n_pages: int, page_size: int, n_kv: int,
               hd: int, dtype) -> PagedPools:
    shp = (num_layers, n_pages, page_size, n_kv, hd)
    return PagedPools(k=jnp.zeros(shp, dtype), v=jnp.zeros(shp, dtype))


def make_pool_scales(num_layers: int, n_pages: int, page_size: int,
                     n_kv: int) -> PoolScales:
    shp = (num_layers, n_pages, page_size, n_kv)
    return PoolScales(k=jnp.ones(shp, jnp.bfloat16),
                      v=jnp.ones(shp, jnp.bfloat16))


def quantize_kv(x):
    """x [B, n_kv, hd] -> (int8 values, bf16 scales [B, n_kv])."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.bfloat16)


POOL_AXES = ("layer", "pages", None, None, None)
POOL_SCALE_AXES = ("layer", "pages", None, None)

# Fused manual-TP decode layout (serve_manual_rules): pages over (pod, data)
# only, KV *heads* over model — each model-axis chip keeps its head slice of
# every page it owns, so attention runs end-to-end on local heads with no
# cross-model K/V gather (serving/engine._make_manual_serve_step).  When the
# model axis is wider than n_kv, the pool head dim is physically TILED to
# n_kv·rep (dist/tp.decode_kv_rep) so the same "kv" mapping divides: each
# chip keeps exactly one (replicated) resident head, and the rep copies stay
# bitwise identical because every owning chip writes its own copy from the
# same replicated inputs.
POOL_AXES_TP = ("layer", "pages", None, "kv", None)
POOL_SCALE_AXES_TP = ("layer", "pages", None, "kv")


class LocalPages(NamedTuple):
    """Per-chip compacted page list (precomputed once per serve step)."""
    rows: jnp.ndarray    # int32[CAP] local pool row (clamped)
    seq: jnp.ndarray     # int32[CAP] owning sequence (B = trash)
    page: jnp.ndarray    # int32[CAP] logical page id
    valid: jnp.ndarray   # bool[CAP]


def compact_local(slots: jnp.ndarray, chip_idx, npr: int,
                  cap: int) -> LocalPages:
    """slots int32[B, maxP] global physical slots (-1 absent).  Select the
    pages this chip owns and compact them into [cap] entries."""
    B, maxP = slots.shape
    flat = slots.reshape(-1)
    mine = (flat >= 0) & (flat // npr == chip_idx)
    pos = jnp.cumsum(mine.astype(jnp.int32)) - 1
    keep = mine & (pos < cap)
    dst = jnp.where(keep, pos, cap)                  # cap = trash slot
    rows = jnp.zeros((cap + 1,), jnp.int32).at[dst].set(
        jnp.where(keep, flat % npr, 0))
    seq = jnp.full((cap + 1,), B, jnp.int32).at[dst].set(
        jnp.where(keep, jnp.arange(B * maxP) // maxP, B))
    page = jnp.zeros((cap + 1,), jnp.int32).at[dst].set(
        jnp.where(keep, jnp.arange(B * maxP) % maxP, 0))
    valid = jnp.zeros((cap + 1,), bool).at[dst].set(keep)
    return LocalPages(rows=rows[:cap], seq=jnp.where(valid[:cap], seq[:cap], B),
                      page=page[:cap], valid=valid[:cap])


def write_token_kv(pool_k_l, pool_v_l, k_new, v_new, write_slot, positions,
                   chip_idx, npr: int, page_size: int, scales=None):
    """Write one token's K/V [B, n_kv, hd] into the page each sequence's
    current position maps to (only on the owning chip).  RoPE is applied by
    the caller BEFORE the write (cache stores rotated keys).  With int8
    pools, ``scales`` is (k_scale_l, v_scale_l) [npr, psize, kv].

    ``write_slot = -1`` is the allocator's ABORT/refusal sentinel
    (page_table.AllocStep): such lanes MUST NOT scatter — the clamp below
    routes them to the dropped row, so a -1 can never wrap (Python-style)
    into the last physical page and corrupt another sequence's KV."""
    mine = (write_slot >= 0) & (write_slot // npr == chip_idx)
    rows = jnp.where(mine, jnp.clip(write_slot, 0) % npr, npr)  # npr -> drop
    offs = positions % page_size
    if pool_k_l.dtype == jnp.int8:
        k_q, k_s = quantize_kv(k_new)
        v_q, v_s = quantize_kv(v_new)
        k_scale_l, v_scale_l = scales
        pool_k_l = pool_k_l.at[rows, offs].set(k_q, mode="drop")
        pool_v_l = pool_v_l.at[rows, offs].set(v_q, mode="drop")
        k_scale_l = k_scale_l.at[rows, offs].set(k_s, mode="drop")
        v_scale_l = v_scale_l.at[rows, offs].set(v_s, mode="drop")
        return pool_k_l, pool_v_l, (k_scale_l, v_scale_l)
    pool_k_l = pool_k_l.at[rows, offs].set(k_new.astype(pool_k_l.dtype),
                                           mode="drop")
    pool_v_l = pool_v_l.at[rows, offs].set(v_new.astype(pool_v_l.dtype),
                                           mode="drop")
    return pool_k_l, pool_v_l, None


def attend_local(q_all, pool_k_l, pool_v_l, lp: LocalPages, positions,
                 page_size: int, scales=None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-chip partial attention.

    q_all [B, n_kv, G, hd] (grouped query, full batch); pools [npr, psize,
    n_kv, hd]; positions [B] current decode position per sequence.
    Returns per-sequence partials (o [B,kv,G,hd] f32, m [B,kv,G], l [B,kv,G])
    ready for cross-chip lse merge."""
    B = q_all.shape[0]
    CAP = lp.rows.shape[0]
    _, psize, n_kv, hd = pool_k_l.shape
    scale = 1.0 / math.sqrt(hd)

    k_loc = pool_k_l[lp.rows]                         # [CAP, psize, kv, hd]
    v_loc = pool_v_l[lp.rows]
    if pool_k_l.dtype == jnp.int8:
        k_scale_l, v_scale_l = scales
        k_loc = (k_loc.astype(jnp.float32)
                 * k_scale_l[lp.rows].astype(jnp.float32)[..., None])
        v_loc = (v_loc.astype(jnp.float32)
                 * v_scale_l[lp.rows].astype(jnp.float32)[..., None])
    seq_c = jnp.minimum(lp.seq, B - 1)
    q_pages = q_all[seq_c]                            # [CAP, kv, G, hd]
    s = jnp.einsum("ckgd,cskd->ckgs", q_pages.astype(jnp.float32),
                   k_loc.astype(jnp.float32)) * scale
    tpos = lp.page[:, None] * page_size + jnp.arange(psize)[None, :]
    ok = lp.valid[:, None] & (tpos <= positions[seq_c][:, None])
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)   # [CAP,kv,G,psize]
    m_p = jnp.max(s, axis=-1)                         # [CAP,kv,G]
    p = jnp.where(ok[:, None, None, :], jnp.exp(s - m_p[..., None]), 0.0)
    l_p = jnp.sum(p, axis=-1)
    o_p = jnp.einsum("ckgs,cskd->ckgd", p, v_loc.astype(jnp.float32))

    # within-chip per-sequence lse merge (scatter-max then weighted adds)
    seq_i = lp.seq                                    # B = trash row
    m_seq = jnp.full((B + 1,) + m_p.shape[1:], NEG_INF).at[seq_i].max(m_p)
    w = jnp.where(lp.valid[:, None, None],
                  jnp.exp(m_p - m_seq[seq_c]), 0.0)
    l_seq = jnp.zeros((B + 1,) + l_p.shape[1:]).at[seq_i].add(l_p * w)
    o_seq = jnp.zeros((B + 1,) + o_p.shape[1:]).at[seq_i].add(
        o_p * w[..., None])
    return o_seq[:B], m_seq[:B], l_seq[:B]


def merge_global(o, m, l, axis_names) -> jnp.ndarray:
    """lse-weighted cross-chip merge.  axis_names=() -> single chip.
    The o partial psums in bf16 (§Perf: halves per-layer merge wire; m/l
    stay f32 — they are hd-times smaller)."""
    if axis_names:
        m_g = jax.lax.pmax(m, axis_names)
        w = jnp.exp(m - m_g)
        o = jax.lax.psum((o * w[..., None]).astype(jnp.bfloat16),
                         axis_names).astype(jnp.float32)
        l = jax.lax.psum(l * w, axis_names)
    return o / jnp.maximum(l, 1e-20)[..., None]


def capacity(B: int, maxP: int, n_chips: int,
             factor: float = 2.0) -> int:
    """Per-chip compacted-page capacity: ``factor``x the uniform share (+8
    slack), rounded to 8.  The hash allocator spreads pages ~uniformly
    (binomial tails), so overflow is negligible even at 1.3x (§Perf run);
    overflowed pages are dropped from attention and surface as a quality
    regression, never a crash (monitored via LocalPages.valid counts)."""
    mean = B * maxP / n_chips
    cap = int(mean * factor) + 8
    return min(B * maxP, -(-cap // 8) * 8)
