"""Serving driver: continuous batching over the paged engine, scheduled by
``repro.serving.sched``.

The driver is deliberately THIN: it owns the engine state and the megastep
dispatch (plus the reactive refused-suffix re-issue safety net); every
admit / evict / preempt / grow decision lives in the scheduler.  One round:

1. build the per-lane teacher-forcing arrays (chunked prefill: a lane whose
   request is still consuming its prompt gets its next <=K prompt tokens
   forced inside the SAME megastep budget the decoding lanes sample under);
2. dispatch ONE K-token megastep (``engine.make_serve_megastep``) — the
   host syncs once per K tokens;
3. absorb the sampled tokens into their requests (TTFT accounting) and, in
   CI mode, verify the incremental block-table cache against the wait-free
   lookup;
4. reactive safety net: if any lane ABORTed (forecaster off / capped), run
   the Section 4.3 rebuild into a 2x pool — the frozen pending token means
   the refused suffix re-issues automatically next round;
5. ask the scheduler for the round's Plan (completions, admissions,
   preemptions, proactive growth) and apply it to the engine state:
   ``free_sequences`` + block-row invalidation for evicted lanes,
   ``rebuild_page_table`` for proactive growth (BEFORE the next dispatch —
   the allocator never aborts and the wait-free read path never sees a
   mid-flight rebuild), fresh sequence ids at position 0 for admissions.

With ``Scheduler(proactive=False)`` the driver degenerates to the old
reactive batcher (admit greedily, rebuild after the abort) — the baseline
the adversarial churn tests compare against.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
      --rounds 6 --batch 4 --max-len 48 --megastep 4 --policy deadline \
      --requests 24 --verify-block-table --fail-on-abort
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as OBS
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.kernels import stats as KS
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT
from repro.serving.sched import (Scheduler, churn_request,
                                 synthetic_workload)

logger = logging.getLogger(__name__)


class ContinuousBatcher:
    """Thin driver: B decode slots, one K-token megastep per round, all
    policy in ``scheduler``.  ``n_pages`` overcommits the page pool (the
    scheduler's headroom controller keeps it out of ABORT); ``auto_refill``
    reproduces the endless eviction-churn stream of the old batcher when no
    explicit workload is submitted."""

    def __init__(self, cfg, params, *, batch: int, max_len: int,
                 page_size: int, rules=None, seed: int = 0,
                 megastep_k: int = 1, verify_block_table: bool = False,
                 scheduler: Scheduler | None = None,
                 n_pages: int | None = None, auto_refill: bool = True,
                 tracer: OBS.Tracer | None = None):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.page_size = batch, max_len, page_size
        self.K = max(1, int(megastep_k))
        self.verify = verify_block_table
        self.auto_refill = auto_refill
        # one facade bound to cfg's probe strategy — every PT call below
        # goes through it so the allocator semantics (and the Headroom
        # slack the scheduler consumes) stay consistent per config
        self.strategy = getattr(cfg, "probe_strategy", "linear")
        self.pt = PT.for_strategy(self.strategy)
        self.state, _ = EG.make_decode_state(cfg, batch, S_max=max_len,
                                             rules=rules,
                                             page_size=page_size,
                                             n_pages=n_pages)
        self.state["active"] = jnp.zeros((batch,), bool)  # no lanes seated
        self.mega_fn = jax.jit(EG.make_serve_megastep(
            cfg, S_max=max_len, K=self.K, rules=rules, page_size=page_size))
        pool = EG.decode_headroom(self.state, strategy=self.strategy)
        self.sched = scheduler or Scheduler(
            slots=batch, page_size=page_size, max_len=max_len,
            megastep_k=self.K)
        self.sched.K = self.K
        self.sched.n_pages = None if pool is None else pool.n_pages
        # telemetry (obs/): span tracer shared with the scheduler, metrics
        # registry absorbing the repo's measurement surfaces, and the
        # cumulative device-counter snapshot the per-K sync differences
        self.tracer = tracer
        self.sched.tracer = tracer
        self.metrics = OBS.MetricsRegistry()
        self.metrics.source("fallback",
                            lambda: EG.fallback_report(cfg, rules))
        self.metrics.source("kernel", lambda: dict(KS.KERNEL_STATS))
        self.metrics.source("probe", lambda: dict(PT.PROBE_STATS))
        self._ctr_prev: dict = {}
        # quiet engine degradations (kernel fallbacks, gspmd decode, oracle
        # probe path) surface once at startup, not only in dryrun/CI
        logger.info("engine fallback report: %s",
                    EG.fallback_report(cfg, rules))
        self.pos = np.zeros(batch, np.int32)
        self.tokens = jnp.zeros((batch, 1), jnp.int32)
        self.next_seq_id = batch
        self.rng = np.random.default_rng(seed + 1)
        self._next_auto_id = 1 << 20          # ids disjoint from workloads
        # per-lane teacher-forcing view (set at admission)
        self.lane_known = [np.zeros((0,), np.int32)] * batch
        self.lane_stop = np.zeros(batch, np.int32)

    # -- compat conveniences ---------------------------------------------

    @property
    def evictions(self) -> int:
        return (self.sched.stats.completed
                + self.sched.stats.preemptive_evictions)

    @property
    def rebuilds(self) -> int:
        return (self.sched.stats.pool_grows
                + self.sched.stats.reactive_rebuilds)

    def table_stats(self):
        if "table" not in self.state:
            return None
        return self.pt.stats(self.state["table"])

    # -- the round --------------------------------------------------------

    def _check_block_table(self):
        mism = int(self.pt.verify_block_table(
            self.state["table"], self.state["seq_ids"],
            jnp.asarray(self.pos), self.state["block_table"],
            page_size=self.page_size))
        if mism:
            raise RuntimeError(
                f"block-table cache diverged from the wait-free lookup "
                f"({mism} entries) — invalidation/update invariant broken")

    def _refill(self):
        """Endless-churn mode: keep the queue deep enough that every free
        slot can re-admit (the old batcher's workload, as Requests)."""
        sch = self.sched
        deficit = self.B - len(sch.running()) - len(sch.queue)
        for _ in range(max(deficit, 0)):
            sch.submit(churn_request(self._next_auto_id, self.rng,
                                     vocab_size=self.cfg.vocab_size,
                                     max_len=self.max_len))
            self._next_auto_id += 1

    def _forcing(self):
        """Teacher-forcing arrays for this round: chunked prefill shares
        the megastep budget with decode (see engine._mega_scan)."""
        B, K = self.B, self.K
        forced = np.zeros((B, K), np.int32)
        fmask = np.zeros((B, K), bool)
        for s, req in enumerate(self.sched.lanes):
            if req is None:
                continue
            known = self.lane_known[s]
            p0 = int(self.pos[s])
            for k in range(K):
                sp = p0 + k + 1
                if sp < known.size:
                    forced[s, k] = known[sp]
                    fmask[s, k] = True
        return forced, fmask

    def _absorb(self, toks: np.ndarray, p0: np.ndarray, p1: np.ndarray):
        """Fold the round's sampled tokens back into their requests.
        ``toks[s, k]`` is the token at sequence position ``p0[s]+k+1``;
        positions below the lane's known length were forced (prompt), at or
        above it they are model samples."""
        clk = self.sched.clock
        for s, req in enumerate(self.sched.lanes):
            if req is None:
                continue
            nk = self.lane_known[s].size
            stop = int(self.lane_stop[s])
            for k in range(int(p1[s]) - int(p0[s])):
                sp = int(p0[s]) + k + 1
                if nk <= sp < stop:
                    req.sampled.append(int(toks[s, k]))
                    if req.first_token_at is None:
                        req.first_token_at = clk
                        self._emit("first_token", req=req.req_id)

    def _apply_plan(self, plan):
        st = self.sched
        evict = plan.evict_slots
        if evict and "table" in self.state:
            mask = np.zeros(self.B, bool)
            mask[evict] = True
            dmask = jnp.asarray(mask)
            maxP = -(-self.max_len // self.page_size)
            t_before = self.state["table"]
            self.state["table"] = self.pt.free_sequences(
                self.state["table"], self.state["seq_ids"],
                jnp.asarray(self.pos), page_size=self.page_size,
                max_pages=maxP, active=dmask)
            if "counters" in self.state:
                # eager scalar adds between rounds — still no extra syncs
                self.state["counters"] = OBS.note_free(
                    self.state["counters"], table_before=t_before,
                    table_after=self.state["table"])
            self.state["block_table"] = self.pt.invalidate_block_rows(
                self.state["block_table"], dmask)
        if evict:
            active = np.asarray(self.state["active"]).copy()
            active[evict] = False
            self.state["active"] = jnp.asarray(active)
        if plan.grow_to is not None and "table" in self.state:
            # PROACTIVE Section 4.3 rebuild: before the abort, between
            # megasteps — the wait-free read path never sees it mid-flight.
            # Traced as "rebuild" (eager, atomic), NOT "grow": only the
            # sharded table's lazy resize opens a frozen-old-table window.
            self.state = EG.rebuild_page_table(self.state,
                                               n_pages=plan.grow_to,
                                               strategy=self.strategy)
            self._emit("rebuild", reason="grow", n_pages=plan.grow_to)
        if plan.admissions:
            seq_ids = np.asarray(self.state["seq_ids"]).copy()
            active = np.asarray(self.state["active"]).copy()
            aborted = np.asarray(self.state["aborted"]).copy()
            tokens = np.asarray(self.tokens).copy()
            self._reset_recurrent_state([s for s, _ in plan.admissions])
            for slot, req in plan.admissions:
                known = req.known_tokens()
                self.lane_known[slot] = known
                self.lane_stop[slot] = st.stop_of(req)
                seq_ids[slot] = self.next_seq_id
                self.next_seq_id += 1
                self.pos[slot] = 0
                active[slot] = True
                aborted[slot] = False
                tokens[slot, 0] = known[0]
                # fresh admissions start at pos 0 with no pages, so the
                # invalidated (-1) block-table rows ARE the correct cache;
                # an admission carrying prefilled pages would rebuild its
                # rows from the wait-free lookup (PageTable.rebuild_block_table)
            self.state["seq_ids"] = jnp.asarray(seq_ids)
            self.state["active"] = jnp.asarray(active)
            self.state["aborted"] = jnp.asarray(aborted)
            self.state["pos"] = jnp.asarray(self.pos)
            self.tokens = jnp.asarray(tokens)

    def _reset_recurrent_state(self, slots):
        """Zero the admitted lanes' PER-LANE recurrent state.  Paged KV
        needs nothing (freed pages are unreachable once the block-table
        rows are invalidated), but the SSM recurrence (mamba ``h`` / conv
        tails) and the ring buffers carry the previous occupant's history
        in-place — a re-seated request must start from the same zero state
        a fresh ``make_decode_state`` would give it, or its decode (and the
        'lossless recompute preemption' invariant) is silently wrong."""
        adm = np.zeros(self.B, bool)
        adm[slots] = True
        amask = jnp.asarray(adm)

        def rows(t, batch_dim, fill):
            shape = [1] * t.ndim
            shape[batch_dim] = -1
            return jnp.where(amask.reshape(shape),
                             jnp.full_like(t, fill), t)

        if "ssm" in self.state:
            self.state["ssm"] = jax.tree.map(
                lambda t: rows(t, 1, 0), self.state["ssm"])
        if "ring_k" in self.state:
            self.state["ring_k"] = rows(self.state["ring_k"], 1, 0)
            self.state["ring_v"] = rows(self.state["ring_v"], 1, 0)
            self.state["ring_pos"] = rows(self.state["ring_pos"], 0, -1)

    def _emit(self, event: str, **fields):
        if self.tracer is not None:
            self.tracer.emit(event, self.sched.clock, **fields)

    def _emit_decode(self, p0: np.ndarray, p1: np.ndarray):
        """Per-round decode span: which requests decoded, how many tokens
        landed, how many page-boundary allocations they implied (derived
        from positions — exact regardless of the telemetry knob)."""
        reqs = [r.req_id for r in self.sched.lanes if r is not None]
        if self.tracer is None or not reqs:
            return
        ps = self.page_size
        pages = 0
        for s, r in enumerate(self.sched.lanes):
            if r is None:
                continue
            pages += sum(1 for p in range(int(p0[s]), int(p1[s]))
                         if p % ps == 0)
        self._emit("decode", reqs=reqs,
                   tokens=int((p1 - p0).sum()), pages=pages)

    def _read_counters(self):
        """Fetch the device counter plane at the per-K sync (the buffers
        are already on their way for ``pos`` — zero extra dispatches) and
        fold the round's delta into the metrics registry."""
        if "counters" not in self.state:
            return None
        snap = OBS.snapshot(self.state["counters"])
        d = OBS.delta(snap, self._ctr_prev)
        self._ctr_prev = snap
        for k, v in d.items():
            if v:
                self.metrics.inc(k, v)
        return d

    def step_round(self):
        """One scheduled megastep round (K tokens per occupied lane)."""
        if self.auto_refill:
            self._refill()
        with PT.probe_stats_scope() as ps:
            forced, fmask = self._forcing()
            p0 = self.pos.copy()
            toks, self.state = self.mega_fn(
                self.params, self.state, self.tokens,
                jnp.asarray(self.lane_stop), jnp.asarray(forced),
                jnp.asarray(fmask))
            self.tokens = toks[:, -1:]       # pending feed (refused token
            self.pos = np.asarray(self.state["pos"]).copy()  # for aborts)
            self.sched.advance(self.K)       # 1 host sync per K tokens
            self._absorb(np.asarray(toks), p0, self.pos)
            self._emit_decode(p0, self.pos)
            if self.verify and "table" in self.state:
                self._check_block_table()
            aborted = self.state.get("aborted")
            n_ab = (0 if aborted is None
                    else int(np.asarray(aborted).sum()))
            if n_ab:
                # REACTIVE safety net (forecaster off / capped / wrong):
                # grow the pool, re-hash, move the KV pages, rebuild the
                # block-table cache, clear the flags; the refused suffix is
                # re-issued by the next megastep at the frozen positions
                n_pages = self.state["pools"].k.shape[1]
                self.state = EG.rebuild_page_table(self.state,
                                                   n_pages=n_pages * 2,
                                                   strategy=self.strategy)
                self.sched.note_aborts(n_ab, grew_to=n_pages * 2)
                self._emit("rebuild", reason="reactive",
                           n_pages=n_pages * 2)
            pool = EG.decode_headroom(self.state, strategy=self.strategy)
            plan = self.sched.plan_round(self.pos, pool)
            self._apply_plan(plan)
            probed = ps["keys_probed"]
        self.metrics.inc("keys_probed", probed)
        ctr = self._read_counters()
        if pool is not None:
            self.metrics.set_gauge("live_pages", pool.live_pages)
            self.metrics.set_gauge("tombstones", pool.tombstones)
            self.metrics.set_gauge("free_cells", pool.free_cells)
            self.metrics.set_gauge("occupancy", pool.occupancy)
        if self.tracer is not None:
            health = None
            if "table" in self.state:
                t = self.state["table"]
                n = int(self.state["pools"].k.shape[1])
                tombs = int(t.num_tombs)
                health = {
                    "live": int(t.num_keys), "tombs": tombs, "n_cells": n,
                    "free": n - int(t.num_keys),
                    "tomb_density": tombs / max(n, 1),
                    "occupancy": (int(t.num_keys) + tombs) / max(n, 1),
                    "probe_p99": PT.PageTable.probe_p99(t),
                    "migrated": 0, "migration_left": 0}
            self._emit("round", counters=ctr, health=health,
                       keys_probed=probed)
        self.sched.end_round(keys_probed=probed)
        return plan

    def decode_round(self, steps: int):
        """Drive ~``steps`` decode steps (ceil(steps / K) rounds)."""
        for _ in range(-(-steps // self.K)):
            self.step_round()

    def run_until_drained(self, max_rounds: int = 1000) -> bool:
        """Run until every submitted request completed (requires
        ``auto_refill=False``).  Returns True when drained."""
        for _ in range(max_rounds):
            if self.sched.drained:
                return True
            self.step_round()
        return self.sched.drained

    # -- telemetry exporters ----------------------------------------------

    def metrics_text(self) -> str:
        """Prometheus text-exposition snapshot of the registry."""
        return self.metrics.prometheus_text()

    def metrics_json(self) -> str:
        """JSON snapshot of the registry (same numbers)."""
        return self.metrics.json_snapshot()

    def emit_summary(self):
        """Final trace line: the scheduler roll-up (invariant 3 of
        tools/trace_report.py reconciles its abort count against the
        trace's abort events)."""
        self._emit("summary", **self.sched.summary())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b", choices=sorted(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=6,
                    help="print intervals (endless churn) or max run length"
                         " x steps-per-round (fixed workload)")
    ap.add_argument("--steps-per-round", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--megastep", type=int, default=4,
                    help="tokens per dispatch (K of make_serve_megastep)")
    ap.add_argument("--policy", default="fcfs",
                    choices=["fcfs", "priority", "deadline"])
    ap.add_argument("--requests", type=int, default=0,
                    help="fixed synthetic workload size (0 = endless churn)")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="stagger arrivals by N steps (0 = storm)")
    ap.add_argument("--slo-fraction", type=float, default=0.5,
                    help="fraction of workload requests carrying an SLO")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="pool size factor vs the worst-case plan (<1 "
                         "overcommits; the headroom controller compensates)")
    ap.add_argument("--no-proactive", action="store_true",
                    help="disable the forecaster/headroom controller "
                         "(reactive baseline: abort -> rebuild)")
    ap.add_argument("--fail-on-abort", action="store_true",
                    help="CI soak: exit non-zero if any allocator ABORT "
                         "surfaced")
    ap.add_argument("--verify-block-table", action="store_true",
                    help="CI/debug: check the incremental block-table "
                         "cache against the wait-free lookup every round")
    ap.add_argument("--probe-strategy", default="linear",
                    choices=["linear", "robinhood", "hopscotch"],
                    help="page-allocator probe strategy (cfg.probe_strategy;"
                         " hopscotch = tombstone-free deletes + scheduler "
                         "slack, see core/probe_strategies.py)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="enable the on-device counter plane "
                         "(cfg.telemetry; read out at the per-K sync)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a deterministic JSONL span trace "
                         "(obs/trace.py; render with tools/trace_report.py)")
    ap.add_argument("--metrics-out", default=None, metavar="PREFIX",
                    help="write PREFIX.prom (Prometheus text) and "
                         "PREFIX.json registry snapshots at exit")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.probe_strategy != cfg.probe_strategy:
        cfg = dataclasses.replace(cfg, probe_strategy=args.probe_strategy)
    if args.telemetry:
        cfg = dataclasses.replace(cfg, telemetry=True)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))

    maxP = -(-args.max_len // args.page_size)
    default_pool = int(args.batch * maxP * 1.25) + 1
    n_pages = max(maxP, int(default_pool * args.overcommit))
    sched = Scheduler(slots=args.batch, page_size=args.page_size,
                      max_len=args.max_len, megastep_k=args.megastep,
                      policy=args.policy,
                      proactive=not args.no_proactive)
    fixed = args.requests > 0
    tracer = OBS.Tracer(args.trace) if args.trace else None
    srv = ContinuousBatcher(cfg, params, batch=args.batch,
                            max_len=args.max_len, page_size=args.page_size,
                            megastep_k=args.megastep,
                            verify_block_table=args.verify_block_table,
                            scheduler=sched, n_pages=n_pages,
                            auto_refill=not fixed, seed=args.seed,
                            tracer=tracer)
    print(f"[serve] fallback report: {EG.fallback_report(cfg, None)}")
    if fixed:
        sched.submit_many(synthetic_workload(
            args.requests, vocab_size=cfg.vocab_size, max_len=args.max_len,
            seed=args.seed, slo_fraction=args.slo_fraction,
            arrival_every=args.arrival_every))

    for r in range(args.rounds):
        srv.decode_round(args.steps_per_round)
        st = srv.table_stats()
        s = sched.stats
        occ = ("" if st is None else
               f" live_pages={int(st.live_pages)} "
               f"tombs={int(st.tombstones)} "
               f"occupancy={float(st.occupancy):.3f}")
        print(f"[serve] round {r}: done={s.completed} "
              f"preempted={s.preemptive_evictions} queue={len(sched.queue)} "
              f"aborts={s.aborts} avoided={s.aborts_avoided} "
              f"grows={s.pool_grows}{occ}")
        if fixed and sched.drained:
            break

    summary = sched.summary()
    print(f"[serve] summary ({sched.policy.name}, "
          f"{'proactive' if sched.proactive else 'reactive'}): "
          + " ".join(f"{k}={v:.0f}" if isinstance(v, float) else f"{k}={v}"
                     for k, v in summary.items()))
    print(f"[serve] done — megastep K={srv.K}: host synced once per K "
          "tokens; page slots were reused in place (no compaction)")
    if tracer is not None:
        srv.emit_summary()
        tracer.close()
        print(f"[serve] trace: {tracer.path} ({tracer.n_events} events)")
    if args.metrics_out:
        d = os.path.dirname(args.metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.metrics_out + ".prom", "w") as f:
            f.write(srv.metrics_text())
        with open(args.metrics_out + ".json", "w") as f:
            f.write(srv.metrics_json())
        print(f"[serve] metrics: {args.metrics_out}.prom / .json")
    if fixed and not sched.drained:
        print("[serve] FAIL: workload not drained")
        return 1
    if args.fail_on_abort and sched.stats.aborts:
        print(f"[serve] FAIL: {sched.stats.aborts} allocator ABORT(s) "
              "surfaced (--fail-on-abort)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
