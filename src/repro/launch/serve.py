"""Serving driver: continuous batching over the paged engine.

Demonstrates the paper's table as the page allocator under realistic churn:
sequences arrive, decode for a while, finish, get EVICTED (delete -> pages
become tombstones), and new sequences immediately RECLAIM those page slots
(tombstone reuse — Proposition 2 as a memory allocator).  The pool never
needs compaction; occupancy stays bounded by live pages.

The decode loop is driven in MEGASTEPS (``engine.make_serve_megastep``):
one jitted dispatch produces K greedy tokens (sampling in-graph), so the
host syncs once per K tokens instead of once per token.  Done lanes latch
``active=False`` in-graph via ``stop_len``; a lane whose page allocation
ABORTs freezes (pos + pending token) and, after the Section 4.3 rebuild,
the next megastep re-issues the refused suffix automatically — the refused
token is still the lane's pending feed.  Eviction/re-admission is one
vectorized host pass per megastep; evicted lanes' block-table rows are
invalidated and re-admitted rows rebuilt from the authoritative wait-free
lookup (the incremental cache never survives a seq-id change).

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
      --rounds 6 --batch 4 --max-len 48 --megastep 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT


class ContinuousBatcher:
    """Slot-based continuous batching: B decode slots; finished sequences
    are evicted (pages freed) and their slot re-admitted with a fresh
    sequence id.  ``megastep_k`` tokens are decoded per dispatch;
    ``verify_block_table=True`` (CI-only) checks the incremental
    block-table cache against the wait-free lookup after every megastep."""

    def __init__(self, cfg, params, *, batch: int, max_len: int,
                 page_size: int, rules=None, seed: int = 0,
                 megastep_k: int = 1, verify_block_table: bool = False):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.page_size = batch, max_len, page_size
        self.K = max(1, int(megastep_k))
        self.verify = verify_block_table
        self.state, _ = EG.make_decode_state(cfg, batch, S_max=max_len,
                                             rules=rules,
                                             page_size=page_size)
        self.mega_fn = jax.jit(EG.make_serve_megastep(
            cfg, S_max=max_len, K=self.K, rules=rules, page_size=page_size))
        self.pos = np.zeros(batch, np.int32)
        self.lengths = np.random.default_rng(seed).integers(
            max_len // 3, max_len - 1, size=batch)
        self.next_seq_id = batch
        self.rng = np.random.default_rng(seed + 1)
        self.evictions = 0
        self.rebuilds = 0
        self.tokens = jnp.zeros((batch, 1), jnp.int32)

    def _check_block_table(self):
        mism = int(PT.verify_block_table(
            self.state["table"], self.state["seq_ids"],
            jnp.asarray(self.pos), self.state["block_table"],
            page_size=self.page_size))
        if mism:
            raise RuntimeError(
                f"block-table cache diverged from the wait-free lookup "
                f"({mism} entries) — invalidation/update invariant broken")

    def decode_round(self, steps: int):
        maxP = -(-self.max_len // self.page_size)
        for _ in range(-(-steps // self.K)):
            toks, self.state = self.mega_fn(
                self.params, self.state, self.tokens,
                jnp.asarray(self.lengths, jnp.int32))
            # the engine is the source of truth: refused lanes' pos did NOT
            # advance and toks[:, -1] is their still-pending refused token
            self.tokens = toks[:, -1:]
            self.pos = np.asarray(self.state["pos"]).copy()  # 1 sync per K
            if self.verify and "table" in self.state:
                self._check_block_table()
            aborted = self.state.get("aborted")
            if aborted is not None and bool(np.asarray(aborted).any()):
                # the Section 4.3 path, live: grow the pool, re-hash, move
                # the KV pages along, rebuild the block-table cache, clear
                # the flags; the refused suffix is re-issued by the next
                # megastep at the frozen positions
                n_pages = self.state["pools"].k.shape[1]
                self.state = EG.rebuild_page_table(self.state,
                                                   n_pages=n_pages * 2)
                self.rebuilds += 1
            self._evict_and_readmit(maxP)

    def _evict_and_readmit(self, maxP: int):
        """One vectorized pass: evict every finished slot (their pages
        become tombstones, their cached block-table rows are invalidated)
        and re-admit a fresh sequence in place."""
        done = self.pos >= self.lengths
        n = int(done.sum())
        if not n:
            return
        dmask = jnp.asarray(done)
        if "table" in self.state:
            self.state["table"] = PT.free_sequences(
                self.state["table"], self.state["seq_ids"],
                jnp.asarray(self.pos), page_size=self.page_size,
                max_pages=maxP, active=dmask)
            self.state["block_table"] = PT.invalidate_block_rows(
                self.state["block_table"], dmask)
        seq_ids = np.asarray(self.state["seq_ids"]).copy()
        seq_ids[done] = self.next_seq_id + np.arange(n, dtype=seq_ids.dtype)
        self.next_seq_id += n
        self.pos[done] = 0
        self.lengths[done] = self.rng.integers(
            self.max_len // 3, self.max_len - 1, size=n)
        self.evictions += n
        self.state["seq_ids"] = jnp.asarray(seq_ids)
        self.state["pos"] = jnp.asarray(self.pos)
        # re-admitted slots decode again (done lanes latched inactive
        # in-graph via stop_len).  Admissions here start at pos 0 with no
        # pages, so the invalidated (-1) rows above ARE the correct cache;
        # an admission that brought prefilled pages would instead rebuild
        # its rows from the authoritative lookup (PT.rebuild_block_table)
        self.state["active"] = jnp.asarray(self.state["active"]) | dmask

    def table_stats(self):
        if "table" not in self.state:
            return None
        return PT.stats(self.state["table"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b", choices=sorted(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--steps-per-round", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--megastep", type=int, default=4,
                    help="tokens per dispatch (K of make_serve_megastep)")
    ap.add_argument("--verify-block-table", action="store_true",
                    help="CI/debug: check the incremental block-table "
                         "cache against the wait-free lookup every round")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    srv = ContinuousBatcher(cfg, params, batch=args.batch,
                            max_len=args.max_len, page_size=args.page_size,
                            megastep_k=args.megastep,
                            verify_block_table=args.verify_block_table)
    for r in range(args.rounds):
        srv.decode_round(args.steps_per_round)
        st = srv.table_stats()
        if st is not None:
            print(f"[serve] round {r}: evictions={srv.evictions} "
                  f"rebuilds={srv.rebuilds} "
                  f"live_pages={int(st.live_pages)} "
                  f"tombstones={int(st.tombstones)} "
                  f"occupancy={float(st.occupancy):.3f}")
        else:
            print(f"[serve] round {r}: evictions={srv.evictions} "
                  f"(attention-free arch: no page table)")
    print(f"[serve] done — megastep K={srv.K}: host synced once per K "
          "tokens; page slots were reused in place (no compaction)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
