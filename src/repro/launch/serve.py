"""Serving driver: continuous batching over the paged engine.

Demonstrates the paper's table as the page allocator under realistic churn:
sequences arrive, decode for a while, finish, get EVICTED (delete -> pages
become tombstones), and new sequences immediately RECLAIM those page slots
(tombstone reuse — Proposition 2 as a memory allocator).  The pool never
needs compaction; occupancy stays bounded by live pages.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
      --rounds 6 --batch 4 --max-len 48
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT


class ContinuousBatcher:
    """Slot-based continuous batching: B decode slots; finished sequences
    are evicted (pages freed) and their slot re-admitted with a fresh
    sequence id."""

    def __init__(self, cfg, params, *, batch: int, max_len: int,
                 page_size: int, rules=None, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.page_size = batch, max_len, page_size
        self.state, _ = EG.make_decode_state(cfg, batch, S_max=max_len,
                                             rules=rules,
                                             page_size=page_size)
        self.step_fn = jax.jit(EG.make_serve_step(cfg, S_max=max_len,
                                                  rules=rules,
                                                  page_size=page_size))
        self.pos = np.zeros(batch, np.int32)
        self.lengths = np.random.default_rng(seed).integers(
            max_len // 3, max_len - 1, size=batch)
        self.next_seq_id = batch
        self.rng = np.random.default_rng(seed + 1)
        self.evictions = 0
        self.rebuilds = 0
        self.tokens = jnp.zeros((batch, 1), jnp.int32)

    def decode_round(self, steps: int):
        maxP = -(-self.max_len // self.page_size)
        for _ in range(steps):
            positions = jnp.asarray(self.pos)
            if self.cfg.family == "vlm":
                mr = jnp.broadcast_to(positions[None, :, None],
                                      (3, self.B, 1)).astype(jnp.int32)
                logits, self.state = self.step_fn(
                    self.params, self.state, self.tokens, positions, mr)
            else:
                logits, self.state = self.step_fn(
                    self.params, self.state, self.tokens, positions)
            prev_tokens = self.tokens
            self.tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            # the engine is the source of truth: aborted lanes refused the
            # token (their pos did NOT advance — we retry after rebuilding)
            self.pos = np.asarray(self.state["pos"]).copy()
            aborted = self.state.get("aborted")
            if aborted is not None and bool(np.asarray(aborted).any()):
                # an aborted lane's logits were computed with its current
                # page missing — keep the REFUSED input token so the
                # post-rebuild retry re-issues it, not a garbage argmax
                self.tokens = jnp.where(jnp.asarray(aborted)[:, None],
                                        prev_tokens, self.tokens)
                # the Section 4.3 path, live: grow the pool, re-hash, move
                # the KV pages along, clear the flags; the refused tokens
                # are re-issued on the next step at the same position
                n_pages = self.state["pools"].k.shape[1]
                self.state = EG.rebuild_page_table(self.state,
                                                   n_pages=n_pages * 2)
                self.rebuilds += 1
            # evict finished sequences; re-admit fresh ones in their slot
            done = np.nonzero(self.pos >= self.lengths)[0]
            if len(done) and "table" in self.state:
                mask = np.zeros(self.B, bool)
                mask[done] = True
                self.state["table"] = PT.free_sequences(
                    self.state["table"], self.state["seq_ids"],
                    jnp.asarray(self.pos), page_size=self.page_size,
                    max_pages=maxP, active=jnp.asarray(mask))
                seq_ids = np.asarray(self.state["seq_ids"]).copy()
                for slot in done:
                    seq_ids[slot] = self.next_seq_id
                    self.next_seq_id += 1
                    self.pos[slot] = 0
                    self.lengths[slot] = self.rng.integers(
                        self.max_len // 3, self.max_len - 1)
                    self.evictions += 1
                self.state["seq_ids"] = jnp.asarray(seq_ids)
            elif len(done):
                for slot in done:
                    self.pos[slot] = 0
                    self.evictions += 1

    def table_stats(self):
        if "table" not in self.state:
            return None
        return PT.stats(self.state["table"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b", choices=sorted(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--steps-per-round", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    srv = ContinuousBatcher(cfg, params, batch=args.batch,
                            max_len=args.max_len, page_size=args.page_size)
    for r in range(args.rounds):
        srv.decode_round(args.steps_per_round)
        st = srv.table_stats()
        if st is not None:
            print(f"[serve] round {r}: evictions={srv.evictions} "
                  f"live_pages={int(st.live_pages)} "
                  f"tombstones={int(st.tombstones)} "
                  f"occupancy={float(st.occupancy):.3f}")
        else:
            print(f"[serve] round {r}: evictions={srv.evictions} "
                  f"(attention-free arch: no page table)")
    print("[serve] done — page slots were reused in place "
          "(no rebuild, no compaction)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
