import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell against ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis()/cost_analysis(), parse the partitioned HLO for collective
bytes, and write one JSON artifact per cell for EXPERIMENTS.md §Dry-run /
§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k --mesh single [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, cell_applicable, input_specs
from repro.dist import sharding as SH
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.training import train_step as TS

# K of the decode-megastep lowering recorded in dry-run artifacts (one scan
# body compile — production K is a serving knob, not a lowering property)
MEGASTEP_K = 4

BATCH_LOGICAL = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "positions": (None,),             # decode: replicated (see engine)
    "src_embeds": ("batch", "seq", None),
    "patch_embeds": ("batch", None, None),
    "mrope_positions": (None, "batch", "seq"),
}


def _abstract(fn, *args):
    """eval_shape that also captures non-array aux output via a box."""
    box = {}

    def wrapped(*a):
        out, aux = fn(*a)
        box["aux"] = aux
        return out

    sds = jax.eval_shape(wrapped, *args)
    return sds, box["aux"]


def _shardings(rules, axes_tree, sds_tree):
    return rules.tree_shardings(axes_tree, sds_tree)


def _batch_shardings(rules, specs, *, decode: bool):
    out = {}
    for k, sds in specs.items():
        logical = BATCH_LOGICAL[k]
        if decode:
            spec = P()
        else:
            spec = rules.spec(logical, sds.shape)
        out[k] = NamedSharding(rules.mesh, spec)
    return out


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               cfg_overrides: dict | None = None):
    """Returns (lowered, compiled, meta) for one cell."""
    overrides = dict(cfg_overrides or {})
    rules_preset = overrides.pop("rules", "default")
    # rolled layer scan (fast compiles); the roofline parser is loop-aware
    cfg = dataclasses.replace(get_config(arch_id), **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    specs = input_specs(cfg, shape)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        rules = SH.dp_rules(mesh) if rules_preset == "dp" \
            else SH.train_rules(mesh)
        state_sds, state_axes = _abstract(
            lambda k: TS.init_state(cfg, k), key)
        state_sh = _shardings(rules, state_axes, state_sds)
        batch_sh = _batch_shardings(rules, specs, decode=False)
        step = TS.make_train_step(cfg, rules=rules)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_sds, specs)

    elif shape.kind == "prefill":
        rules = SH.dp_rules(mesh) if rules_preset == "dp" \
            else SH.train_rules(mesh)   # prefill is compute-bound like train
        params_sds, axes = _abstract(lambda k: model.init(cfg, k), key)
        params_sh = _shardings(rules, axes, params_sds)
        batch_sh = _batch_shardings(rules, specs, decode=False)

        def prefill_step(params, batch):
            from repro.dist import ctx
            with ctx.use_rules(rules):
                kw = {}
                if "src_embeds" in batch:
                    kw["src_embeds"] = batch["src_embeds"]
                if "patch_embeds" in batch:
                    kw["patch_embeds"] = batch["patch_embeds"]
                    kw["mrope_positions"] = batch["mrope_positions"]
                logits, _ = model.forward(cfg, params, batch["tokens"],
                                          remat=False, last_only=True, **kw)
            return logits

        jitted = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh))
        lowered = jitted.lower(params_sds, specs)

    else:  # decode
        # tp_impl=manual uses the fused-decode layout (pages over pod/data,
        # KV heads over model) — but only when the fused region actually
        # applies; otherwise keep the baseline pages-over-every-axis layout
        # (the engine falls back to the gspmd step, and logs why).
        man_rules = SH.serve_manual_rules(mesh)
        fused = EG._manual_decode_ok(cfg, man_rules)
        rules = man_rules if fused else SH.serve_rules(mesh)
        params_sds, axes = _abstract(lambda k: model.init(cfg, k), key)
        params_sh = _shardings(rules, axes, params_sds)
        B = shape.global_batch
        state_sds, state_axes = EG.make_decode_state(
            cfg, B, S_max=shape.seq_len, rules=rules, abstract=True)
        state_sh = _shardings(rules, state_axes, state_sds)
        # decode cells lower the K-token MEGASTEP (it contains the per-token
        # serve body, so the old single-step gate is subsumed): in-graph
        # greedy sampling, positions from state["pos"], vlm mrope derived
        # in-graph.  The artifact records the megastep tag so a regression
        # back to per-token host dispatch fails --expect-fused.
        serve = EG.make_serve_megastep(cfg, S_max=shape.seq_len,
                                       K=MEGASTEP_K, rules=rules)
        megastep_tag = getattr(serve, "megastep", "per-token")

        def serve_step(params, state, tokens):
            return serve(params, state, tokens)
        tok_sh = NamedSharding(mesh, P())
        jitted = jax.jit(serve_step,
                         in_shardings=(params_sh, state_sh, tok_sh),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_sds, state_sds, specs["tokens"])

    compiled = lowered.compile()
    from repro.serving.sharded_table import plan_table_shards
    meta = {"arch": arch_id, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "kind": shape.kind,
            # page-table shards this mesh serves with: one per pod-axis
            # host group (serving/sharded_table.plan_table_shards)
            "table_shards": plan_table_shards(mesh)}
    if shape.kind == "decode":
        # every gated fast-path fallback from ONE structure
        # (engine.fallback_report — the same reason functions the step
        # factories log from): artifacts must prove the fast paths applied,
        # never a quiet fallback (--expect-fused / --expect-fused-kernel).
        # Evaluated against the manual rules — the gate that decided which
        # rules this cell lowered under — so decode_tp matches the path.
        report = EG.fallback_report(cfg, man_rules)
        meta["decode_tp"] = ("manual-fused" if report["decode_tp"] == "ok"
                             else "gspmd")
        meta["megastep"] = megastep_tag
        # "ok" or the reason decode attention did NOT lower as the
        # one-dispatch fused Pallas probe+attention kernel
        meta["fused_kernel"] = report["fused_kernel"]
        # "<name>: ok" or "<name>: <reason>" — which allocator probe
        # strategy the cell serves and whether any accelerated path
        # degraded to the jnp oracle for it
        meta["probe_strategy"] = report["probe_strategy"]
        if cfg.family == "hybrid":
            # whether the mamba backbone lowered HEAD-SHARDED over model
            # (decode_ssm_tp) or as replicated redundant compute
            from repro.dist import tp as TP
            meta["mamba_tp"] = (
                "sharded-model" if fused
                and TP.decode_ssm_tp(cfg, mesh.shape["model"])
                else "replicated")
    return cfg, shape, lowered, compiled, meta


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, cfg_overrides: dict | None = None,
             tag_suffix: str = "") -> dict:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch_id}__{shape_name}__{mesh_name}{tag_suffix}"
    ok, why = cell_applicable(cfg, shape)
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                 "overrides": cfg_overrides or {}}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(out_dir, tag, rec)
        return rec

    t0 = time.time()
    try:
        cfg, shape, lowered, compiled, meta = lower_cell(
            arch_id, shape_name, multi_pod, cfg_overrides=cfg_overrides)
        t_compile = time.time() - t0
        mf = RL.model_flops(cfg, shape)
        from repro.launch.flops_model import (executed_bytes_per_chip,
                                              executed_flops)
        ex = executed_flops(cfg, shape)
        eb = executed_bytes_per_chip(cfg, shape, meta["chips"], 16)
        rl = RL.extract(compiled, arch=arch_id, shape_name=shape_name,
                        mesh_name=mesh_name, chips=meta["chips"],
                        model_flops_total=mf,
                        executed_flops_total=ex.total,
                        executed_bytes_per_chip=eb)
        rec["flops_breakdown"] = dataclasses.asdict(ex)
        mem = compiled.memory_analysis()
        mem_rec = {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                    "alias_size_in_bytes") if hasattr(mem, k)}
        rec.update(status="ok", compile_s=round(t_compile, 1),
                   kind=meta["kind"], table_shards=meta["table_shards"],
                   memory_analysis=mem_rec,
                   roofline=rl.to_dict())
        if "decode_tp" in meta:
            rec["decode_tp"] = meta["decode_tp"]
            rec["megastep"] = meta["megastep"]
            rec["fused_kernel"] = meta["fused_kernel"]
            rec["probe_strategy"] = meta["probe_strategy"]
            if "mamba_tp" in meta:
                rec["mamba_tp"] = meta["mamba_tp"]
        if verbose:
            print(f"[{tag}] compiled in {t_compile:.0f}s  "
                  f"flops/chip={rl.hlo_flops_per_chip:.3e}  "
                  f"bytes/chip={rl.hlo_bytes_per_chip:.3e}  "
                  f"coll_wire={rl.collective_wire_bytes:.3e}  "
                  f"dom={rl.dominant}  frac={rl.roofline_fraction:.3f}")
            print(f"  memory_analysis: {mem_rec}")
    except Exception as e:  # noqa: BLE001 — record, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{tag}] FAILED: {type(e).__name__}: {e}")
    _save(out_dir, tag, rec)
    return rec


def _save(out_dir: str, tag: str, rec: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. tp_impl=manual)")
    ap.add_argument("--tag", default="", help="artifact name suffix")
    ap.add_argument("--expect-fused", default="",
                    help="comma-separated archs whose decode cells MUST "
                         "take the fused manual-TP path (exit 1 on any "
                         "quiet gspmd fallback)")
    ap.add_argument("--expect-fused-kernel", default="",
                    help="comma-separated archs whose decode cells MUST "
                         "lower the one-dispatch fused probe+attention "
                         "Pallas kernel (artifact fused_kernel == 'ok'; "
                         "exit 1 on any quiet two-dispatch fallback)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    archs = sorted(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.out,
                                        cfg_overrides=overrides,
                                        tag_suffix=args.tag))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells")
    not_fused = []
    if args.expect_fused:
        expect = {a.strip() for a in args.expect_fused.split(",") if a}
        seen = set()
        for r in results:
            if (r["arch"] not in expect or r["status"] != "ok"
                    or SHAPES[r["shape"]].kind != "decode"):
                continue
            seen.add(r["arch"])
            if r.get("decode_tp") != "manual-fused":
                not_fused.append(f"{r['arch']}/{r['shape']}/{r['mesh']}")
            elif not str(r.get("megastep", "")).startswith("scan-"):
                # the K-token scan dispatch silently degraded to per-token
                not_fused.append(f"{r['arch']}/{r['shape']}/{r['mesh']}"
                                 f" (megastep={r.get('megastep')})")
            elif not str(r.get("probe_strategy", ": ok")).endswith(": ok"):
                # a requested probe strategy quietly degraded an
                # accelerated path to the jnp oracle — same fallback
                # discipline as the TP region (engine.fallback_report)
                not_fused.append(f"{r['arch']}/{r['shape']}/{r['mesh']}"
                                 f" (probe_strategy="
                                 f"{r.get('probe_strategy')})")
        # an expected arch with NO ok decode cell (typo / rename / all
        # skipped) must fail too, or the gate is silently vacuous
        for arch in sorted(expect - seen):
            not_fused.append(f"{arch}/<no ok decode cell>")
        if not_fused:
            print("expect-fused VIOLATED (quiet gspmd fallback): "
                  + ", ".join(not_fused))
    no_kernel = []
    if args.expect_fused_kernel:
        expect_k = {a.strip() for a in args.expect_fused_kernel.split(",")
                    if a}
        seen_k = set()
        for r in results:
            if (r["arch"] not in expect_k or r["status"] != "ok"
                    or SHAPES[r["shape"]].kind != "decode"):
                continue
            seen_k.add(r["arch"])
            if r.get("fused_kernel") != "ok":
                no_kernel.append(f"{r['arch']}/{r['shape']}/{r['mesh']}"
                                 f" (fused_kernel={r.get('fused_kernel')})")
        # same vacuous-gate protection as --expect-fused: an expected arch
        # with no ok decode cell must fail, not silently pass
        for arch in sorted(expect_k - seen_k):
            no_kernel.append(f"{arch}/<no ok decode cell>")
        if no_kernel:
            print("expect-fused-kernel VIOLATED (quiet two-dispatch "
                  "fallback): " + ", ".join(no_kernel))
    return 0 if n_err == 0 and not not_fused and not no_kernel else 1


if __name__ == "__main__":
    raise SystemExit(main())
