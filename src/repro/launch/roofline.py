"""Roofline-term extraction from compiled dry-run artifacts.

Sources (EXPERIMENTS.md §Roofline):
* ``compiled.cost_analysis()`` — per-device HLO FLOPs and bytes accessed
  (the compiled module is the post-SPMD per-device program).
* ``compiled.as_text()`` — the partitioned HLO; collective bytes are NOT in
  cost_analysis, so we parse every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute and sum operand sizes.
* ``compiled.memory_analysis()`` — proves the per-device footprint fits.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_shapes(line: str):
    """Result shape(s) of an HLO instruction line (handles tuples)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return []
    rhs = lhs[1]
    op_end = rhs.find("(")
    shape_str = rhs[:op_end] if op_end > 0 else rhs
    return _SHAPE_RE.findall(shape_str)


def _group_size(line: str) -> Optional[int]:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return None


def _line_collective(s: str):
    """(kind, operand_bytes, wire_bytes, promoted) of one HLO line, or None.

    ``promoted``: XLA:CPU promotes bf16 collectives to f32 (its collective
    kernels lack bf16), wrapping the operand in a convert — detectable as an
    f32 collective whose operand fusion carries a ``convert`` marker.  On
    the TPU target these run in bf16, so the corrected wire bytes halve.
    """
    for kind in COLLECTIVE_OPS:
        # match ` all-reduce(` or ` all-reduce-start(`
        if f" {kind}(" in s or f" {kind}-start(" in s:
            shapes = _result_shapes(s)
            if not shapes:
                return None
            bytes_res = sum(_shape_bytes(dt, dm) for dt, dm in shapes)
            promoted = (all(dt == "f32" for dt, _ in shapes)
                        and "convert" in s.split("(", 1)[1][:120])
            g = _group_size(s) or 1
            if kind == "all-gather":
                operand = bytes_res / max(g, 1)
                wire = bytes_res * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                operand = bytes_res * g
                wire = bytes_res * (g - 1) / max(g, 1)
            elif kind == "all-reduce":
                operand = bytes_res
                wire = 2 * bytes_res * (g - 1) / max(g, 1)
            elif kind == "all-to-all":
                operand = bytes_res
                wire = bytes_res * (g - 1) / max(g, 1)
            else:  # collective-permute
                operand = bytes_res
                wire = bytes_res
            return kind, operand, wire, promoted
    return None


_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HEAD_RE.match(line.strip()) if "{" in line else None
        if m and " = " not in line.split("{")[0]:
            cur = m.group(2)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list) -> int:
    """Trip count of a lax.scan-style while: the condition compares the
    induction variable to a constant bound.  Dynamic bounds (flash kv loop)
    have no constant -> assume 1 (those loops carry no collectives)."""
    consts = []
    for line in cond_lines:
        if "compare(" in line or "constant(" in line:
            consts += [int(x) for x in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Loop-aware collective accounting from partitioned HLO.

    XLA keeps lax.scan as a `while`, so a naive line scan counts per-layer
    collectives once.  We split the module into computations, read each
    while's trip count from its condition's constant bound, and multiply the
    body's collectives by the product of enclosing trip counts (nested scans
    compose, e.g. SSD chunks inside the layer scan).

    operand_bytes: per-device operand sizes (the assignment's metric).
    wire_bytes: ring-algorithm bytes crossing links per device —
      all-reduce 2·(g-1)/g·size, all-gather/reduce-scatter (g-1)/g·full,
      all-to-all (g-1)/g·size, collective-permute 1·size.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line.strip())
            if m:
                entry = m.group(2)
    out = {k: {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0,
               "wire_bytes_tpu": 0.0}
           for k in COLLECTIVE_OPS}
    if entry is None:    # fallback: flat scan
        comps = {"__all__": hlo_text.splitlines()}
        entry = "__all__"

    def visit(comp: str, mult: float, seen: tuple):
        if comp not in comps or comp in seen:
            return
        for line in comps[comp]:
            s = line.strip()
            hit = _line_collective(s)
            if hit is not None:
                kind, operand, wire, promoted = hit
                out[kind]["count"] += mult
                out[kind]["operand_bytes"] += operand * mult
                out[kind]["wire_bytes"] += wire * mult
                out[kind]["wire_bytes_tpu"] += \
                    wire * mult * (0.5 if promoted else 1.0)
                continue
            m = _WHILE_RE.search(s)
            if m:
                cond, body = m.group(1), m.group(2)
                trip = _trip_count(comps.get(cond, []))
                visit(body, mult * trip, seen + (comp,))
            elif " call(" in s or "conditional(" in s:
                for name in re.findall(r"to_apply=%?([\w.\-]+)", s):
                    visit(name, mult, seen + (comp,))

    visit(entry, 1.0, ())
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float      # raw cost_analysis (loops undercounted)
    executed_flops_total: float    # analytic executed FLOPs (flops_model)
    hlo_bytes_per_chip: float      # raw cost_analysis (diagnostic)
    executed_bytes_per_chip: float # analytic HBM traffic (flops_model)
    collective_operand_bytes: float
    collective_wire_bytes: float       # as parsed (CPU-promoted f32)
    collective_wire_bytes_tpu: float   # bf16-native on the TPU target
    collective_breakdown: Dict[str, Dict[str, float]]
    model_flops_total: float
    peak_memory_per_chip: float

    @property
    def compute_s(self) -> float:
        return self.executed_flops_total / self.chips / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.executed_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_wire_bytes_tpu / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return (self.model_flops_total / self.executed_flops_total
                if self.executed_flops_total else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time / achievable step time (bound = max of terms)."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        ideal = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return ideal / bound if bound else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train (fwd+bwd), 2·N·D prefill, 2·N_active·B/step
    decode.  N excludes embedding-table rows that a step doesn't touch?  No —
    standard convention: N = all non-embedding params + embeddings counted
    once via the logits matmul; we use the analytic param_count (MoE:
    active)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def extract(compiled, *, arch: str, shape_name: str, mesh_name: str,
            chips: int, model_flops_total: float,
            executed_flops_total: float,
            executed_bytes_per_chip: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    mem = compiled.memory_analysis()
    peak = float(getattr(mem, "temp_size_in_bytes", 0) +
                 getattr(mem, "argument_size_in_bytes", 0) +
                 getattr(mem, "output_size_in_bytes", 0) -
                 getattr(mem, "alias_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops,
        executed_flops_total=executed_flops_total,
        hlo_bytes_per_chip=byts,
        executed_bytes_per_chip=executed_bytes_per_chip,
        collective_operand_bytes=sum(v["operand_bytes"]
                                     for v in coll.values()),
        collective_wire_bytes=sum(v["wire_bytes"] for v in coll.values()),
        collective_wire_bytes_tpu=sum(v["wire_bytes_tpu"]
                                      for v in coll.values()),
        collective_breakdown=coll,
        model_flops_total=model_flops_total,
        peak_memory_per_chip=peak,
    )
