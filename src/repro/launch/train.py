"""Production train runner: data -> train_step -> checkpoint, wired with the
fault-tolerance layer (watchdog, straggler monitor, SDC canary, elastic
restore).  Runs the same loop at every scale: smoke configs on one CPU
device, full configs on the production mesh.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --smoke \
      --steps 20 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.dist import fault_tolerance as FT
from repro.dist import sharding as SH
from repro.training import checkpoint as CKPT
from repro.training import data as DATA
from repro.training import train_step as TS


class TrainRunner:
    """Checkpointed, watchdogged train loop (restartable by construction:
    batches are a pure function of step)."""

    def __init__(self, cfg, *, rules=None, ckpt_dir=None, ckpt_every=50,
                 deadline_s=3600.0, dedup=False):
        self.cfg = cfg
        self.rules = rules
        self.step_fn = jax.jit(TS.make_train_step(cfg, rules=rules))
        self.ckpt = (CKPT.CheckpointManager(ckpt_dir)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.watchdog = FT.StepWatchdog(deadline_s)
        self.straggler = FT.StragglerMonitor()
        self.dedup = DATA.DedupState() if dedup else None
        self.canary_fp = None

    def init_or_restore(self, key):
        state, axes = TS.init_state(self.cfg, key)
        self.axes = axes
        start = 0
        if self.ckpt is not None and CKPT.latest_step(self.ckpt.dir) is not None:
            state, start = self.ckpt.restore_latest(state, rules=self.rules)
            print(f"[train] restored checkpoint at step {start}")
        return state, start

    def run(self, *, batch: int, seq_len: int, steps: int, seed: int = 0,
            log_every: int = 10):
        state, start = self.init_or_restore(jax.random.PRNGKey(seed))
        it = DATA.make_batch_iterator(self.cfg, batch=batch, seq_len=seq_len,
                                      seed=seed, start_step=start,
                                      dedup=self.dedup)
        losses = []
        for step, b in it:
            if step >= steps:
                break
            b.pop("keep", None)
            b.pop("dup_frac", None)
            self.watchdog.arm(step)
            t0 = time.monotonic()
            state, metrics = self.step_fn(state, b)
            loss = float(metrics["loss"])   # sync point
            dt = time.monotonic() - t0
            self.watchdog.check()
            verdict = self.straggler.observe(step, dt)
            if verdict == "replan":
                print(f"[train] step {step}: persistent straggler — a real "
                      f"deployment would re-shard / swap in a hot spare")
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save_async(step + 1, state, self.axes)
        if self.ckpt is not None:
            self.ckpt.save_async(steps, state, self.axes)
            self.ckpt.wait()
        return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b", choices=sorted(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dedup", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    runner = TrainRunner(cfg, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, dedup=args.dedup)
    t0 = time.time()
    _, losses = runner.run(batch=args.batch, seq_len=args.seq,
                           steps=args.steps, seed=args.seed)
    if not losses:
        print(f"[train] checkpoint already at step >= {args.steps}; "
              f"nothing to do")
        return 0
    print(f"[train] {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert np.isfinite(losses).all(), "NaN/inf loss"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
