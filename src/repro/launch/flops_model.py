"""Analytic EXECUTED-FLOPs model per (arch × shape).

Why analytic: XLA's HloCostAnalysis counts a ``while`` body once, so any
rolled loop (layer scan, flash-attention kv loop, SSD chunk scan) is
undercounted by its trip count.  The dry-run unrolls the *layer* scan so the
partitioned HLO carries the true per-layer collectives, but inner loops
(flash kv chunks, SSD chunks) must stay rolled — so the roofline compute
term uses this model instead.  It counts what the compiled program actually
executes, including:

* remat recompute (nothing_saveable layer policy: dense matmuls 4x fwd,
  flash attention fwd + replay + 5-matmul backward = 9 units / 2 fwd units),
* TP head padding (qwen1.5/2.5 40->48, qwen2-vl 28->32),
* flash kv-chunk rounding of the causal triangle,
* MoE dispatch capacity over-compute (capacity_factor) + router,
* paged-decode page-capacity over-read factor (~2x live tokens),
* the logits matmul (by far the largest single op for big-vocab models).

``ideal`` is the 6·N·D / 2·N·D / 2·N·B convention (MODEL_FLOPS) — the ratio
executed/ideal is the waste diagnostic reported in §Roofline.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import DEFAULT_KV_CHUNK, DEFAULT_Q_CHUNK


@dataclasses.dataclass
class FlopsBreakdown:
    attn_proj: float = 0.0
    attn_score: float = 0.0
    mlp: float = 0.0
    ssm: float = 0.0
    logits: float = 0.0
    router: float = 0.0

    @property
    def total(self) -> float:
        return (self.attn_proj + self.attn_score + self.mlp + self.ssm
                + self.logits + self.router)


def _attn_proj_flops(cfg, T) -> float:
    """qkv + o projections, padded head counts (the executed shapes)."""
    d, hd = cfg.d_model, cfg.hd
    return 2.0 * T * d * hd * (2 * cfg.n_q + 2 * cfg.n_kv)


def _attn_score_flops(cfg, B, S, *, window=0, causal=True, Sk=None) -> float:
    """scores + pv matmuls (one forward pass)."""
    hd = cfg.hd
    Sk = Sk if Sk is not None else S
    if window and causal:
        eff = min(window + DEFAULT_KV_CHUNK / 2, Sk)   # chunk rounding
        pairs = B * S * eff
    elif causal:
        # triangle at kv-chunk granularity
        pairs = B * S * (Sk / 2 + DEFAULT_KV_CHUNK / 2)
    else:
        pairs = B * S * Sk
    return 2.0 * 2.0 * cfg.n_q * hd * pairs            # qk + pv


def _mlp_flops(cfg, T, d_ff=None) -> float:
    return 2.0 * 3.0 * T * cfg.d_model * (d_ff or cfg.d_ff)


def _moe_flops(cfg, T) -> float:
    rows = T * cfg.experts_per_token * cfg.moe_capacity_factor
    expert = 2.0 * 3.0 * rows * cfg.d_model * cfg.d_ff
    router = 2.0 * T * cfg.d_model * cfg.num_experts
    return expert + router


def _ssm_flops(cfg, B, S) -> float:
    """Mamba2 block: projections + conv + SSD core (one forward)."""
    T = B * S
    d, di = cfg.d_model, cfg.d_inner
    G, N = cfg.ssm_groups, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    proj = 2.0 * T * d * (2 * di + 2 * G * N + H) + 2.0 * T * di * d
    conv = 2.0 * T * (di + 2 * G * N) * cfg.conv_width
    # SSD: scores CB^T [Q x Q x G x N], intra y [Q x Q x H x P],
    # state in/out [S x H x P x N each]
    nc = max(S // Q, 1)
    ssd = (2.0 * B * nc * Q * Q * G * N          # C B^T
           + 2.0 * B * nc * Q * Q * H * P        # M @ xdt
           + 2.0 * 2.0 * B * S * H * P * N)      # state update + readout
    return proj + conv + ssd


def _logits_flops(cfg, T) -> float:
    return 2.0 * T * cfg.d_model * cfg.vocab_size


# multipliers: fwd / fwd+bwd-with-remat
_DENSE_TRAIN = 4.0        # fwd + remat replay + 2x bwd
_ATTN_TRAIN = 4.5         # (2 fwd + 2 replay + 5 bwd) / 2 fwd units
_NO_REMAT_TRAIN = 3.0     # logits: fwd + 2x bwd (not inside remat scan)

PAGE_CAPACITY_WASTE = 2.0  # decode gathers ~2x the live pages (capacity)


def executed_flops(cfg: ModelConfig, shape: ShapeConfig) -> FlopsBreakdown:
    B, S = shape.global_batch, shape.seq_len
    fb = FlopsBreakdown()

    if shape.kind in ("train", "prefill"):
        T = B * S
        dense_m = _DENSE_TRAIN if shape.kind == "train" else 1.0
        attn_m = _ATTN_TRAIN if shape.kind == "train" else 1.0
        head_m = _NO_REMAT_TRAIN if shape.kind == "train" else 1.0
        T_logits = T if shape.kind == "train" else B  # prefill: last_only

        if cfg.family in ("dense", "moe", "vlm"):
            L = cfg.num_layers
            if cfg.pattern_local:
                ng = L // (cfg.pattern_local + 1)
                n_local = ng * cfg.pattern_local
                n_global = ng
                fb.attn_score += attn_m * (
                    n_local * _attn_score_flops(cfg, B, S,
                                                window=cfg.local_window)
                    + n_global * _attn_score_flops(cfg, B, S))
            else:
                fb.attn_score += attn_m * L * _attn_score_flops(cfg, B, S)
            fb.attn_proj += dense_m * L * _attn_proj_flops(cfg, T)
            if cfg.family == "moe":
                fb.mlp += dense_m * L * _moe_flops(cfg, T)
            else:
                fb.mlp += dense_m * L * _mlp_flops(cfg, T)
        elif cfg.family == "ssm":
            fb.ssm += dense_m * cfg.num_layers * _ssm_flops(cfg, B, S)
        elif cfg.family == "hybrid":
            n_inv = cfg.num_layers // cfg.shared_attn_every
            fb.ssm += dense_m * cfg.num_layers * _ssm_flops(cfg, B, S)
            fb.attn_proj += dense_m * n_inv * _attn_proj_flops(cfg, T)
            fb.attn_score += attn_m * n_inv * _attn_score_flops(cfg, B, S)
            fb.mlp += dense_m * n_inv * _mlp_flops(cfg, T)
        elif cfg.family == "encdec":
            S_src = max(S // 8, 1)
            T_src = B * S_src
            Le, Ld = cfg.encoder_layers, cfg.num_layers
            fb.attn_proj += dense_m * (Le * _attn_proj_flops(cfg, T_src)
                                       + 2 * Ld * _attn_proj_flops(cfg, T))
            fb.attn_score += attn_m * (
                Le * _attn_score_flops(cfg, B, S_src, causal=False)
                + Ld * _attn_score_flops(cfg, B, S)
                + Ld * _attn_score_flops(cfg, B, S, causal=False, Sk=S_src))
            fb.mlp += dense_m * (Le + Ld) * _mlp_flops(cfg, T)
        fb.logits += head_m * _logits_flops(cfg, T_logits)

    else:  # decode: one token per sequence, context length S
        T = B
        live = B * S * PAGE_CAPACITY_WASTE
        if cfg.family in ("dense", "moe", "vlm"):
            L = cfg.num_layers
            if cfg.pattern_local:
                ng = L // (cfg.pattern_local + 1)
                fb.attn_score += 2.0 * 2.0 * cfg.n_q * cfg.hd * (
                    ng * cfg.pattern_local * B * cfg.local_window
                    + ng * live)
            else:
                fb.attn_score += 2.0 * 2.0 * cfg.n_q * cfg.hd * L * live
            fb.attn_proj += L * _attn_proj_flops(cfg, T)
            if cfg.family == "moe":
                fb.mlp += L * _moe_flops(cfg, T)
            else:
                fb.mlp += L * _mlp_flops(cfg, T)
        elif cfg.family == "ssm":
            # O(1) recurrence per token
            d, di = cfg.d_model, cfg.d_inner
            G, N, H, P = (cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads,
                          cfg.ssm_head_dim)
            per = (2.0 * T * d * (2 * di + 2 * G * N + H) + 2.0 * T * di * d
                   + 2.0 * 2.0 * T * H * P * N)
            fb.ssm += cfg.num_layers * per
        elif cfg.family == "hybrid":
            d, di = cfg.d_model, cfg.d_inner
            G, N, H, P = (cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads,
                          cfg.ssm_head_dim)
            per = (2.0 * T * d * (2 * di + 2 * G * N + H) + 2.0 * T * di * d
                   + 2.0 * 2.0 * T * H * P * N)
            fb.ssm += cfg.num_layers * per
            n_inv = cfg.num_layers // cfg.shared_attn_every
            fb.attn_proj += n_inv * _attn_proj_flops(cfg, T)
            fb.attn_score += 2.0 * 2.0 * cfg.n_q * cfg.hd * n_inv * live
            fb.mlp += n_inv * _mlp_flops(cfg, T)
        elif cfg.family == "encdec":
            S_src = max(S // 8, 1)
            Ld = cfg.num_layers
            fb.attn_proj += 2 * Ld * _attn_proj_flops(cfg, T)
            fb.attn_score += 2.0 * 2.0 * cfg.n_q * cfg.hd * Ld * (
                live + B * S_src)
            fb.mlp += Ld * _mlp_flops(cfg, T)
        fb.logits += _logits_flops(cfg, B)
    return fb


# ---------------------------------------------------------------------------
# Analytic per-chip HBM traffic (the memory roofline term).
#
# cost_analysis "bytes accessed" undercounts rolled loops exactly like flops,
# so the memory term uses this coarse model (documented coefficients):
#   * weights: read once per pass; per-chip traffic = N·2B / tp (TP slices are
#     local; FSDP gathers materialize the full d-dim before the matmul reads)
#   * activations: ACT_RW r/w events per layer on the residual-stream-sized
#     tensor (q/k/v/scores/mlp-hidden/norms/residuals, averaged)
#   * optimizer: m,v f32 read+write + param read/write, ZeRO-sharded
#   * decode: page-pool reads x capacity waste + recurrent/ring state
# Reported next to cost_analysis bytes (kept as a diagnostic).

ACT_RW = 12.0


def executed_bytes_per_chip(cfg: ModelConfig, shape: ShapeConfig,
                            chips: int, tp: int) -> float:
    n_params = cfg.param_count()
    w_pass = n_params * 2.0 / tp
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model

    if shape.kind in ("train", "prefill"):
        dp = max(chips // tp, 1)
        tokens_chip = B * S / dp
        act = tokens_chip * d * 2.0
        L_eff = cfg.num_layers + (cfg.encoder_layers or 0)
        passes = 3.0 if shape.kind == "train" else 1.0
        total = passes * w_pass + passes * ACT_RW * L_eff * act
        if shape.kind == "train":
            total += 24.0 * n_params / chips          # AdamW m/v/param r+w
            total += 2.0 * n_params * 2.0 / chips     # grad write+read
        return total

    # decode — one token per sequence
    total = w_pass                                     # weights re-read
    n_paged, n_ring = 0, 0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        if cfg.pattern_local:
            g = cfg.pattern_local + 1
            n_paged = cfg.num_layers // g
            n_ring = cfg.num_layers - n_paged
        else:
            n_paged = cfg.num_layers
    elif cfg.family == "hybrid":
        n_paged = cfg.num_layers // cfg.shared_attn_every
    kv_bytes = (1.0 + 2.0 / cfg.hd if cfg.kv_cache_dtype == "int8"
                else 2.0)                              # + bf16 scale row
    kv_row = cfg.n_kv * cfg.hd * 2 * kv_bytes          # K+V per token
    total += cfg.page_capacity_factor * n_paged * B * S * kv_row / chips
    total += n_ring * B * cfg.local_window * kv_row / chips
    if cfg.family in ("ssm", "hybrid"):
        state = (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4.0
                 * B * cfg.num_layers)
        total += 2.0 * state / chips                   # read + write
    if cfg.family == "encdec":
        S_src = max(S // 8, 1)
        total += cfg.num_layers * B * S_src * kv_row / chips
    return total
