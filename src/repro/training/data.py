"""Deterministic, restart-safe synthetic data pipeline with hash-table-based
n-gram dedup.

Batches are a pure function of (seed, step): restoring a checkpoint needs
only the step counter — no iterator state, no host-side files.  Token
streams are Zipf-distributed (realistic softmax/embedding access skew).

Dedup (the paper's table in the data path): every sequence contributes
8-gram fingerprints; a batched lock-free-analog hash table (core/batched)
keeps the seen-set — duplicate-heavy sequences are masked out of the loss.
Tombstone reuse lets the dedup window *slide* (old fingerprints deleted,
cells reclaimed) without ever rebuilding the table — exactly the paper's
space story, in the substrate.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import batched as BT


def synth_batch(cfg, *, batch: int, seq_len: int, step: int,
                seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Batch of next-token LM data: tokens [B,S] and labels (shift-by-one)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    # Zipf-ish marginal over the vocab via exponential transform
    u = jax.random.uniform(key, (batch, seq_len + 1), minval=1e-6)
    ranks = jnp.floor(jnp.exp(jnp.log(float(cfg.vocab_size)) * u)) - 1
    toks = jnp.clip(ranks.astype(jnp.int32), 0, cfg.vocab_size - 1)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "encdec":
        k2 = jax.random.fold_in(key, 1)
        out["src_embeds"] = jax.random.normal(
            k2, (batch, max(seq_len // 8, 1), cfg.d_model),
            cfg.activation_dtype())
    if cfg.family == "vlm":
        k3 = jax.random.fold_in(key, 2)
        n_patch = min(256, seq_len // 2)
        out["patch_embeds"] = jax.random.normal(
            k3, (batch, n_patch, cfg.d_model), cfg.activation_dtype())
        pos = jnp.arange(seq_len)[None, None]
        out["mrope_positions"] = jnp.broadcast_to(
            pos, (3, batch, seq_len)).astype(jnp.int32)
    return out


# ---------------------------------------------------------------------------
# n-gram dedup on the paper's hash table.

NGRAM = 8
FPR_PER_SEQ = 16  # fingerprints sampled per sequence


def _fingerprints(tokens: jnp.ndarray, n: int = NGRAM,
                  k: int = FPR_PER_SEQ) -> jnp.ndarray:
    """tokens [B,S] -> uint32[B,k] rolling-hash n-gram fingerprints at k
    evenly spaced offsets."""
    B, S = tokens.shape
    offs = jnp.linspace(0, max(S - n - 1, 0), k).astype(jnp.int32)
    idx = offs[None, :, None] + jnp.arange(n)[None, None, :]   # [1,k,n]
    grams = jnp.take_along_axis(
        tokens[:, None, :], jnp.broadcast_to(idx, (B, k, n)), axis=2)
    h = jnp.zeros((B, k), jnp.uint32)
    for i in range(n):
        h = h * jnp.uint32(0x01000193) ^ grams[:, :, i].astype(jnp.uint32)
    return h % jnp.uint32(BT.E.MAX_KEY)


class DedupState:
    """Sliding-window dedup: fingerprints inserted now are deleted
    ``window`` batches later (tombstone reuse keeps occupancy bounded)."""

    def __init__(self, m: int = 1 << 16, window: int = 64):
        self.table = BT.create(m, seed=7)
        self.window = window
        self.ring: list = []

    def filter_batch(self, tokens: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (keep_mask bool[B], dup_frac scalar).  A sequence is a
        duplicate if most of its fingerprints are already in the table."""
        fps = _fingerprints(tokens)
        B, k = fps.shape
        flat = fps.reshape(-1)
        seen = BT.lookup_batch(self.table, flat).reshape(B, k)
        dup_frac = jnp.mean(seen, axis=1)
        keep = dup_frac < 0.5
        self.table, _ = BT.insert_batch(self.table, flat)
        self.ring.append(flat)
        if len(self.ring) > self.window:
            old = self.ring.pop(0)
            self.table, _ = BT.delete_batch(self.table, old)
        return keep, jnp.mean(dup_frac)


def make_batch_iterator(cfg, *, batch: int, seq_len: int, seed: int = 0,
                        start_step: int = 0, dedup: Optional[DedupState] = None):
    """Stateless-per-step iterator (restart-safe); optional dedup masking
    (keep mask multiplies the loss weights downstream)."""
    step = start_step
    while True:
        b = synth_batch(cfg, batch=batch, seq_len=seq_len, step=step,
                        seed=seed)
        if dedup is not None:
            keep, frac = dedup.filter_batch(b["tokens"])
            b["keep"] = keep
            b["dup_frac"] = frac
        yield step, b
        step += 1
