"""AdamW with global-norm clipping, cosine schedule, and FSDP/ZeRO-sharded
state.

Optimizer state (m, v in f32) inherits each parameter's logical axes, so the
same ShardingRules that shard a parameter shard its moments — ZeRO-3 falls
out of the rules table ("embed" -> data axis) with no special casing.
Parameters stay in the model dtype (bf16); updates are computed in f32 and
cast on write (stochastic rounding would slot in here on real hardware).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any          # f32 pytree, like params
    v: Any          # f32 pytree, like params
    count: jnp.ndarray


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def opt_state_axes(param_axes) -> OptState:
    """Logical axes for the optimizer state pytree (mirrors params)."""
    return OptState(m=param_axes, v=param_axes, count=())


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params, opt: OptState,
          grads) -> Tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m2 / b1c
        vh = v2 / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p32
        return (p32 - lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    params2 = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    m2 = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    v2 = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params2, OptState(m=m2, v=v2, count=count), metrics
