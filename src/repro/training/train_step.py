"""Train-step factories.

``make_train_step`` — the GSPMD path: loss -> grad -> clip -> AdamW, with
activation remat on the layer scan and logical sharding constraints from the
active ``dist.ctx`` rules.  Gradient reduction over (pod, data) is inserted
by autodiff/GSPMD (batch is sharded over those axes).

``make_train_step_manual_pod`` — the distributed-optimization variant for
DCN-separated pods: the pod axis is handled *manually* (shard_map at the top
level), so the cross-pod gradient all-reduce is explicit and runs through
``dist.compression`` (int8 + error feedback), overlapping nothing it
shouldn't.  Used by the multi-pod dry-run as the compressed-DP configuration.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import compression
from repro.dist import ctx
from repro.dist.compat import axis_size, shard_map
from repro.models.registry import get_model
from repro.training import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState
    step: jnp.ndarray


def init_state(cfg, key) -> tuple[TrainState, Any]:
    model = get_model(cfg)
    params, axes = model.init(cfg, key)
    state = TrainState(params=params, opt=opt.init(params),
                       step=jnp.zeros((), jnp.int32))
    state_axes = TrainState(params=axes, opt=opt.opt_state_axes(axes),
                            step=())
    return state, state_axes


def make_loss_fn(cfg, remat: bool = True) -> Callable:
    model = get_model(cfg)

    def loss_fn(params, batch):
        kwargs = {}
        if "src_embeds" in batch:
            kwargs["src_embeds"] = batch["src_embeds"]
        if cfg.family == "vlm":
            logits, aux = model.forward(
                cfg, params, batch["tokens"],
                patch_embeds=batch.get("patch_embeds"),
                mrope_positions=batch.get("mrope_positions"),
                remat=remat)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                                     axis=-1)[..., 0]
            return -jnp.mean(ll) + (0.01 * aux / cfg.num_layers
                                    if cfg.family == "moe" else 0.0)
        return model.loss_fn(cfg, params, batch["tokens"], batch["labels"],
                             remat=remat, **kwargs)

    return loss_fn


def make_train_step(cfg, adamw: Optional[opt.AdamWConfig] = None,
                    remat: bool = True, rules=None) -> Callable:
    adamw = adamw or opt.AdamWConfig()
    loss_fn = make_loss_fn(cfg, remat=remat)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        with ctx.use_rules(rules):
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
            params2, opt2, metrics = opt.apply(adamw, state.params,
                                               state.opt, grads)
        metrics["loss"] = loss
        return TrainState(params2, opt2, state.step + 1), metrics

    return train_step


def make_train_step_manual_pod(cfg, mesh,
                               adamw: Optional[opt.AdamWConfig] = None,
                               remat: bool = True, rules=None) -> Callable:
    """Cross-pod compressed-gradient variant.  Params are replicated over
    ``pod`` (FSDP/TP sharding *within* a pod via ``rules``); the batch is
    manually split over pods; per-pod grads are reduced over the pod axis
    with int8 error-feedback compression, then the optimizer runs
    identically on every pod."""
    assert "pod" in mesh.shape, "manual-pod step needs a pod axis"
    adamw = adamw or opt.AdamWConfig()
    loss_fn = make_loss_fn(cfg, remat=remat)

    dp_axes = tuple(a for a in ("data",) if a in mesh.shape)

    def train_step(state: TrainState, err, batch):
        """``err`` leaves carry a leading [npods] dim (per-pod residuals),
        sharded over the pod axis.  The region is fully manual (the pinned
        XLA rejects partially-auto regions around the attention loops — see
        dist/compat.py): the batch is split over (pod, data), grads are
        pmean'd over ``data`` uncompressed (cheap ICI), then reduced over
        ``pod`` through int8 error-feedback compression (the expensive DCN
        hop).  The model axis sees replicated inputs and computes
        redundantly — identical on every chip, so the optimizer stays
        bitwise in sync."""
        bsp = P(("pod",) + dp_axes)
        batch_specs = jax.tree.map(lambda _: bsp, batch)
        err_specs = jax.tree.map(lambda _: P("pod"), err)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), err_specs, batch_specs),
            out_specs=(P(), err_specs, P(), P()),
            check_vma=False)
        def _pod_step(state, err, batch):
            err_local = jax.tree.map(lambda e: e[0], err)
            with ctx.use_rules(rules):
                loss, grads = jax.value_and_grad(loss_fn)(state.params,
                                                          batch)
                if dp_axes:   # within-pod DP mean, uncompressed
                    grads = jax.tree.map(
                        lambda g: jax.lax.pmean(g, dp_axes), grads)
                grads, err2 = compression.tree_compressed_psum(
                    grads, "pod", err_local)
                npods = axis_size("pod")
                grads = jax.tree.map(lambda g: g / npods, grads)
                loss = jax.lax.pmean(loss, ("pod",) + dp_axes)
                params2, opt2, metrics = opt.apply(adamw, state.params,
                                                   state.opt, grads)
            err2 = jax.tree.map(lambda e: e[None], err2)
            return (TrainState(params2, opt2, state.step + 1), err2, loss,
                    metrics["grad_norm"])

        state2, err2, loss, gnorm = _pod_step(state, err, batch)
        return state2, err2, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_pod_error_buffers(params, npods: int):
    """Per-pod error-feedback residuals, leading [npods] dim (pod-sharded)."""
    return jax.tree.map(
        lambda p: jnp.zeros((npods,) + p.shape, jnp.float32), params)
