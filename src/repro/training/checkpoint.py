"""Sharded, atomic, elastic checkpointing.

Design for the 1000+-node deployment (DESIGN.md §5):

* **Atomic commit** — state is written to ``step_<N>.tmp/`` and
  ``os.rename``d to ``step_<N>/`` only after every leaf + manifest is
  fsync'd; a crash mid-save never corrupts the latest checkpoint.
* **Mesh-agnostic / elastic restore** — leaves are stored as full logical
  arrays keyed by pytree path, with the *logical axes* recorded in the
  manifest.  Restore re-shards onto whatever mesh/rules the new job brings
  (different pod count, different TP width): ``restore(..., rules=...)``
  device_puts each leaf with the sharding derived from its recorded logical
  axes — elastic scaling falls out of the logical-axes indirection.
* **Multi-host note** — in a real multi-controller deployment each host
  writes only the shards it owns (jax.experimental.multihost_utils /
  array_serialization do this); this single-process build writes full
  arrays but keeps the same directory/manifest format.
* **Async save** — ``save_async`` snapshots to host RAM synchronously
  (cheap) and writes to disk on a worker thread, so the train loop stalls
  only for the device->host copy, not the filesystem.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _is_axes_leaf(x) -> bool:
    """Logical-axes tuples (('vocab','embed'), (), (None,'batch')) are LEAVES
    of the axes tree — without this they'd flatten element-wise and the
    manifest keys would never match the state keys."""
    return isinstance(x, tuple) and all(e is None or isinstance(e, str)
                                        for e in x)


def _flatten(tree, axes: bool = False) -> dict:
    flat = {}
    kw = {"is_leaf": _is_axes_leaf} if axes else {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree, **kw)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, state, state_axes=None,
         extra: Optional[dict] = None) -> str:
    """Atomic checkpoint of a pytree.  Returns the committed path.

    A step that is already committed is left untouched: training is
    restart-deterministic (batches are a pure function of step), so the
    state at a given step is content-identical — skipping keeps the commit
    unconditionally atomic (no rename shuffle with crash windows)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": int(step), "leaves": {}, "extra": extra or {}}
    if state_axes is not None:
        ax_flat = _flatten(state_axes, axes=True)
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        entry = {"file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
        if state_axes is not None and key in ax_flat:
            ax = ax_flat[key]
            entry["logical_axes"] = list(ax) if isinstance(ax, tuple) else None
        manifest["leaves"][key] = entry
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)          # the atomic commit point
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and d.split("_")[1].isdigit()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_template, *, step: Optional[int] = None,
            rules=None) -> Tuple[Any, int]:
    """Restore into the template's structure.  With ``rules`` (ShardingRules
    for the *current* mesh), every leaf is device_put with the sharding
    derived from its recorded logical axes — elastic restore onto a
    different mesh shape."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_template = _flatten(state_template)
    out = {}
    for key, tmpl in flat_template.items():
        entry = manifest["leaves"][key]
        arr = np.load(os.path.join(path, entry["file"]))
        if arr.dtype.kind == "V":   # np.load gives void for bf16 etc.
            import ml_dtypes  # noqa: F401 — registers extended dtypes
            arr = arr.view(np.dtype(entry["dtype"]))
        if rules is not None and entry.get("logical_axes") is not None:
            from jax.sharding import NamedSharding
            spec = rules.spec(tuple(entry["logical_axes"]), arr.shape)
            arr = jax.device_put(arr, NamedSharding(rules.mesh, spec))
        out[key] = arr

    leaves_paths = jax.tree_util.tree_flatten_with_path(state_template)
    keys_in_order = ["/".join(_path_str(p) for p in path_)
                     for path_, _ in leaves_paths[0]]
    treedef = leaves_paths[1]
    restored = jax.tree_util.tree_unflatten(
        treedef, [out[k] for k in keys_in_order])
    return restored, step


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class CheckpointManager:
    """keep-N rotation + async disk writes."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, state, state_axes=None) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()

        def _write():
            save(self.dir, step, host_state, state_axes)
            prune(self.dir, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template, rules=None):
        return restore(self.dir, template, rules=rules)
