"""Sharded, atomic, elastic checkpointing.

Design for the 1000+-node deployment (DESIGN.md §5):

* **Atomic commit** — state is written to ``step_<N>.tmp/`` and
  ``os.rename``d to ``step_<N>/`` only after every leaf + manifest is
  fsync'd; a crash mid-save never corrupts the latest checkpoint.
* **Mesh-agnostic / elastic restore** — leaves are stored as full logical
  arrays keyed by pytree path, with the *logical axes* recorded in the
  manifest.  Restore re-shards onto whatever mesh/rules the new job brings
  (different pod count, different TP width): ``restore(..., rules=...)``
  device_puts each leaf with the sharding derived from its recorded logical
  axes — elastic scaling falls out of the logical-axes indirection.
* **Multi-host note** — in a real multi-controller deployment each host
  writes only the shards it owns (jax.experimental.multihost_utils /
  array_serialization do this); this single-process build writes full
  arrays but keeps the same directory/manifest format.
* **Async save** — ``save_async`` snapshots to host RAM synchronously
  (cheap) and writes to disk on a worker thread, so the train loop stalls
  only for the device->host copy, not the filesystem.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _is_axes_leaf(x) -> bool:
    """Logical-axes tuples (('vocab','embed'), (), (None,'batch')) are LEAVES
    of the axes tree — without this they'd flatten element-wise and the
    manifest keys would never match the state keys."""
    return isinstance(x, tuple) and all(e is None or isinstance(e, str)
                                        for e in x)


def _flatten(tree, axes: bool = False) -> dict:
    flat = {}
    kw = {"is_leaf": _is_axes_leaf} if axes else {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree, **kw)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _atomic_json(path: str, doc: dict) -> None:
    """Write/overwrite a JSON file atomically: tmp + fsync + os.replace —
    safe even when ``path`` already exists (the re-save path)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(ckpt_dir: str, step: int, state, state_axes=None,
         extra: Optional[dict] = None) -> str:
    """Atomic checkpoint of a pytree.  Returns the committed path.

    A step that is already committed keeps its LEAVES untouched: training
    is restart-deterministic (batches are a pure function of step), so the
    state at a given step is content-identical — skipping the leaf rewrite
    keeps the commit unconditionally atomic (no rename shuffle with crash
    windows).  The ``extra`` METADATA is different: it can legitimately
    change between re-saves of the same step (the shard manifest after an
    elastic remesh is the motivating case), so a re-save merges the new
    ``extra`` into the committed manifest atomically (tmp + ``os.replace``)
    instead of silently dropping it."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):
        if extra:
            mpath = os.path.join(final, "manifest.json")
            with open(mpath) as f:
                manifest = json.load(f)
            merged = {**manifest.get("extra", {}), **extra}
            if merged != manifest.get("extra"):
                manifest["extra"] = merged
                _atomic_json(mpath, manifest)
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": int(step), "leaves": {}, "extra": extra or {}}
    if state_axes is not None:
        ax_flat = _flatten(state_axes, axes=True)
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        entry = {"file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
        if state_axes is not None and key in ax_flat:
            ax = ax_flat[key]
            entry["logical_axes"] = list(ax) if isinstance(ax, tuple) else None
        manifest["leaves"][key] = entry
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)          # the atomic commit point
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and d.split("_")[1].isdigit()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_template, *, step: Optional[int] = None,
            rules=None) -> Tuple[Any, int]:
    """Restore into the template's structure.  With ``rules`` (ShardingRules
    for the *current* mesh), every leaf is device_put with the sharding
    derived from its recorded logical axes — elastic restore onto a
    different mesh shape."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_template = _flatten(state_template)
    out = {}
    for key, tmpl in flat_template.items():
        entry = manifest["leaves"][key]
        arr = np.load(os.path.join(path, entry["file"]))
        if arr.dtype.kind == "V":   # np.load gives void for bf16 etc.
            import ml_dtypes  # noqa: F401 — registers extended dtypes
            arr = arr.view(np.dtype(entry["dtype"]))
        if rules is not None and entry.get("logical_axes") is not None:
            from jax.sharding import NamedSharding
            spec = rules.spec(tuple(entry["logical_axes"]), arr.shape)
            arr = jax.device_put(arr, NamedSharding(rules.mesh, spec))
        out[key] = arr

    leaves_paths = jax.tree_util.tree_flatten_with_path(state_template)
    keys_in_order = ["/".join(_path_str(p) for p in path_)
                     for path_, _ in leaves_paths[0]]
    treedef = leaves_paths[1]
    restored = jax.tree_util.tree_unflatten(
        treedef, [out[k] for k in keys_in_order])
    return restored, step


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


# ---------------------------------------------------------------------------
# Multi-host sharded checkpoints (the distributed page table's format).
#
# Each host writes ONLY the shard it owns (``save_shard`` — atomic
# per-shard dir), and the step becomes visible only when ``commit_sharded``
# lands ``shards.json`` (written LAST, via tmp + os.replace).  Because the
# commit file replace is atomic even when the step is already committed,
# re-committing with a NEW shard manifest — after an elastic remesh moved
# prefix ranges, or after resharding — updates the checkpoint in place with
# no crash window.  Restore is shard-count-agnostic: the saved unit is raw
# per-shard arrays + the routing manifest, and the reader re-homes them
# onto however many shards the new job brings.


def save_shard(ckpt_dir: str, step: int, shard_id: int, state,
               extra: Optional[dict] = None) -> str:
    """One host's shard write: ``step_<N>/shard_<S>/`` (atomic tmp+rename;
    a re-save of the same shard replaces it).  NOT a commit — the step
    stays invisible to ``latest_sharded_step`` until ``commit_sharded``."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(final, exist_ok=True)
    sdir = os.path.join(final, f"shard_{shard_id:04d}")
    tmp = sdir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"shard": int(shard_id), "leaves": {}, "extra": extra or {}}
    for key, leaf in _flatten(state).items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(sdir):
        shutil.rmtree(sdir)
    os.rename(tmp, sdir)
    return sdir


def commit_sharded(ckpt_dir: str, step: int,
                   shard_manifest: Optional[dict] = None,
                   extra: Optional[dict] = None) -> str:
    """The commit point: enumerate the written shard dirs and land
    ``shards.json`` atomically.  ``shard_manifest`` carries the routing
    manifest (``ShardManifest.to_json`` parsed dict) so restore knows the
    prefix -> owner map the shards were written under."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    shards = sorted(d for d in os.listdir(final)
                    if d.startswith("shard_") and not d.endswith(".tmp"))
    assert shards, f"commit_sharded({step}) with no shard dirs"
    _atomic_json(os.path.join(final, "shards.json"),
                 {"step": int(step), "shards": shards,
                  "shard_manifest": shard_manifest, "extra": extra or {}})
    return os.path.join(final, "shards.json")


def latest_sharded_step(ckpt_dir: str) -> Optional[int]:
    """Latest COMMITTED sharded step (shards.json present)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and d.split("_")[1].isdigit()
             and os.path.exists(os.path.join(ckpt_dir, d, "shards.json"))]
    return max(steps) if steps else None


def restore_sharded(ckpt_dir: str, *, step: Optional[int] = None
                    ) -> Tuple[list, Optional[dict], int]:
    """Read every shard of a committed sharded step as raw arrays (no
    template — shard payloads are variable-length).  Returns
    ``([{key: array, ..., "_extra": dict} per shard], shard_manifest,
    step)``; the caller re-homes the payloads onto its own shard count."""
    step = latest_sharded_step(ckpt_dir) if step is None else step
    assert step is not None, f"no committed sharded checkpoint in {ckpt_dir}"
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "shards.json")) as f:
        doc = json.load(f)
    out = []
    for sdir in doc["shards"]:
        with open(os.path.join(final, sdir, "manifest.json")) as f:
            manifest = json.load(f)
        shard = {"_extra": manifest.get("extra", {})}
        for key, entry in manifest["leaves"].items():
            shard[key] = np.load(os.path.join(final, sdir, entry["file"]))
        out.append(shard)
    return out, doc.get("shard_manifest"), step


class CheckpointManager:
    """keep-N rotation + async disk writes."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, state, state_axes=None) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self.wait()

        def _write():
            save(self.dir, step, host_state, state_axes)
            prune(self.dir, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template, rules=None):
        return restore(self.dir, template, rules=rules)
