"""ProbeStrategy: pluggable probe order / claim arbitration / deletion mode.

The batched table (``core/batched.py``) hard-codes the paper's linear probe:
scan ``h(v), h(v)+1, ...``; claim EMPTY-or-TOMBSTONE cells with lowest-
batch-index scatter-min arbitration; delete by tombstoning.  This module
extracts that contract into a strategy object so the serving stack can pick
the allocator behaviour per workload (PAPERS.md: Concurrent Robin Hood
Hashing, Lock-Free Hopscotch Hashing).  Three strategies:

``linear``
    The paper's algorithm, bitwise-unchanged — ``batched.py`` keeps the
    original implementation inline and this class merely delegates to it
    (the recorded-trace parity test pins it to the pre-refactor behaviour).

``robinhood``
    Same probe sequence, same tombstone deletion, but the scatter-min
    arbitration priority IS the displacement (distance already travelled
    from the home bucket): a lane that has probed further wins contested
    cells, with batch index only as the tiebreaker.  This is Robin Hood's
    variance bound translated to the batched CAS analog — the existing
    claim mechanism, a different priority key.  Lookup/deletion/ABORT
    semantics are identical to linear, so the forecaster's exact no-ABORT
    bound (free_cells = n_pages - live) carries over unchanged.

``hopscotch``
    Neighborhood hashing: ``meta[h]`` is a uint32 bitmap — bit ``d`` set
    iff cell ``(h + d) mod m`` holds a key homed at ``h`` (``d < H``,
    H = min(32, m)).  Lookups gather at most H bitmap-indicated cells —
    bounded and wait-free (no EMPTY-terminated scan).  Deletes clear the
    cell back to EMPTY and clear the home bit: NO tombstones, ever, so
    ``free_cells`` counts EMPTY cells exactly.  Inserts claim the first
    EMPTY cell inside the neighborhood (same scatter-min arbitration);
    when the first EMPTY lies outside, the classic hop displacement walks
    it backwards by relocating residents within their own neighborhoods.
    Displacement can fail below full load, so unlike linear/robinhood,
    ``free_cells > 0`` is NOT a sufficient no-ABORT condition — the
    forecaster must keep ``forecast_slack()`` extra headroom and the
    reactive §4.3 rebuild path stays live (see core/README.md).

Concurrency note (honest scope): between batch applications the table is
quiescent, so hopscotch's relocating delete/displacement run at batch
boundaries with no concurrent readers — we get the *space* behaviour of
Lock-Free Hopscotch (tombstone-free deletion, bounded lookups) without
needing its in-flight COLLIDED/marker protocol.  The displacement loop
resolves one lane per arbitration round; in-neighborhood claims stay fully
data-parallel.

Strategy identity is a STATIC Python string (never a pytree leaf): it is
threaded as a keyword through ``batched.py`` / bound once into the
``serving.page_table.PageTable`` facade, so jit caches one program per
strategy and the HashTable pytree stays numeric-only.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import batched as BT
from repro.core import encoding as E

# Hopscotch neighborhood size: capped by the uint32 bitmap carrier.  Tables
# smaller than 32 cells use H = m (the neighborhood covers the whole table,
# so displacement is never needed there).
H_NEIGHBORHOOD = 32


def _finalize_insert_ret(keys, act, leader, present, placed, aborted):
    """Shared insert return-code post-processing (mirrors linear's inline
    code): 1=inserted, 0=present/duplicate/inactive, 2=ABORT, with a
    non-leader duplicate of an aborted leader also aborting."""
    ret = jnp.zeros(keys.shape, jnp.int32)
    ret = jnp.where(placed, 1, ret)
    ret = jnp.where(aborted, 2, ret)
    B = keys.shape[0]
    eq = keys[None, :] == keys[:, None]
    earlier = jnp.tril(jnp.ones((B, B), bool), k=-1)
    leader_aborted = jnp.any(eq & earlier & aborted[None, :], axis=1)
    ret = jnp.where(act & ~leader & ~present & leader_aborted, 2, ret)
    return ret


class ProbeStrategy:
    """The contract a probe strategy must satisfy (see core/README.md):

    * ``find_batch`` is WAIT-FREE: pure vectorized reads, no lane's result
      depends on another lane's in-flight writes.
    * ``insert_batch``/``delete_batch`` leave the table QUIESCENT and equal
      to a sequential execution of some serialization of the batch; returns
      match the by-batch-index serialization.
    * ``num_keys``/``num_tombs`` counters stay exact, so the scheduler's
      ``Headroom`` view is exact; ``forecast_slack`` states how much extra
      headroom the forecaster must hold for the no-ABORT proof to apply.
    """

    name: str = ""
    #: deletes leave TOMBSTONE cells (reused by inserts, Prop. 2)
    uses_tombstones: bool = True
    #: the Pallas probe kernel (kernels/probe) assumes this probe order
    kernel_supported: bool = False

    def forecast_slack(self, n_pages: int) -> int:
        """Extra free cells the forecaster must hold beyond exact demand for
        ``demand + slack <= free_cells`` to guarantee no ABORT."""
        return 0

    def init_meta(self, m: int) -> jnp.ndarray:
        """Per-entry metadata arrays as one extra uint32 pytree leaf on
        ``HashTable`` (empty for metadata-free strategies)."""
        return jnp.zeros((0,), jnp.uint32)

    def find_batch(self, ht, keys, active=None):
        raise NotImplementedError

    def insert_batch(self, ht, keys, active=None, claim_tombstones=True):
        raise NotImplementedError

    def delete_batch(self, ht, keys, active=None):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# linear — delegate to the inline implementation in batched.py.


class LinearStrategy(ProbeStrategy):
    name = "linear"
    uses_tombstones = True
    kernel_supported = True

    def find_batch(self, ht, keys, active=None):
        return BT.find_batch(ht, keys, active, strategy="linear")

    def insert_batch(self, ht, keys, active=None, claim_tombstones=True):
        return BT.insert_batch(ht, keys, active, claim_tombstones,
                               strategy="linear")

    def delete_batch(self, ht, keys, active=None):
        return BT.delete_batch(ht, keys, active, strategy="linear")


# ---------------------------------------------------------------------------
# robinhood — linear probe, displacement-ordered claim arbitration.


class RobinHoodStrategy(LinearStrategy):
    name = "robinhood"
    # the Pallas probe kernel only performs LOOKUPS, and robinhood lookups
    # are bitwise the linear scan (claims only ever land on available cells
    # walked in probe order, so a key's run still contains no EMPTY cell
    # and the kernel's EMPTY-terminated sweep stays exact)
    kernel_supported = True

    def insert_batch(self, ht, keys, active=None, claim_tombstones=True):
        """Linear's arbitration loop with displacement as the priority.

        At every round each pending lane's displacement IS its cursor (it
        has probed ``cursor`` cells past its home bucket), so the priority
        key ``(m - 1 - cursor) * B + lane`` makes the furthest-travelled
        lane win each contested cell under the same scatter-MIN, with batch
        index as the tiebreaker.  Probe sequence, tombstone reuse, ABORT
        condition and return codes are identical to linear."""
        keys = jnp.asarray(keys, dtype=jnp.uint32)
        m = BT.size(ht)
        B = keys.shape[0]
        # priority fits int32: displacement < m, tiebreak < B
        assert m * B < 2**31, "robinhood priority key overflows int32"
        act = BT._active_mask(B, active)
        hv = BT._hash(ht, keys)
        leader = BT._dedup_leaders(keys, act)
        present, _ = self.find_batch(ht, keys, act)

        lane = jnp.arange(B, dtype=jnp.int32)
        sentinel = jnp.int32(m * B)

        def cond(st):
            table, cursor, pending, placed, aborted, tombs_used = st
            return jnp.any(pending)

        def body(st):
            table, cursor, pending, placed, aborted, tombs_used = st
            cand = jnp.mod(hv + cursor, m)
            if claim_tombstones:
                avail = E.is_available(table[cand]) & pending
            else:
                avail = (table[cand] == jnp.uint32(E.EMPTY)) & pending
            disp = jnp.clip(cursor, 0, m - 1)
            pri = (jnp.int32(m - 1) - disp) * B + lane
            claim_idx = jnp.where(avail, cand, m)  # OOB -> dropped
            claims = jnp.full((m,), sentinel, jnp.int32).at[claim_idx].min(
                pri, mode="drop")
            won = avail & (claims[cand] == pri)
            was_tomb = won & (table[cand] == jnp.uint32(E.TOMBSTONE))
            write_idx = jnp.where(won, cand, m)
            table = table.at[write_idx].set((keys << 2) | E.TAG_FINAL,
                                            mode="drop")
            tombs_used = tombs_used + jnp.sum(was_tomb)
            placed = placed | won
            adv = pending & ~won
            cursor = jnp.where(adv, cursor + 1, cursor)
            ab = adv & (cursor >= m)
            aborted = aborted | ab
            pending = pending & ~won & ~ab
            return table, cursor, pending, placed, aborted, tombs_used

        st0 = (ht.table, jnp.zeros((B,), jnp.int32), leader & ~present,
               jnp.zeros((B,), bool), jnp.zeros((B,), bool), jnp.int32(0))
        table, _, _, placed, aborted, tombs_used = jax.lax.while_loop(
            cond, body, st0)

        ret = _finalize_insert_ret(keys, act, leader, present, placed,
                                   aborted)
        ht2 = ht._replace(table=table,
                          num_keys=ht.num_keys + jnp.sum(placed),
                          num_tombs=ht.num_tombs - tombs_used)
        return ht2, ret


# ---------------------------------------------------------------------------
# hopscotch — neighborhood bitmaps, relocating tombstone-free deletes.


class HopscotchStrategy(ProbeStrategy):
    name = "hopscotch"
    uses_tombstones = False
    kernel_supported = False

    def neighborhood(self, m: int) -> int:
        return min(H_NEIGHBORHOOD, m)

    def forecast_slack(self, n_pages: int) -> int:
        # when the neighborhood covers the whole table, near-claim sees
        # every EMPTY cell and inserts abort only on a truly full pool —
        # free_cells is exact (Prop. 2 analog) and no slack is needed.
        if n_pages <= H_NEIGHBORHOOD:
            return 0
        # otherwise displacement can fail with ~H contiguous live cells
        # blocking a neighborhood even while free cells exist elsewhere;
        # holding H cells of slack makes that practically unreachable (and
        # the reactive rebuild path stays live regardless).
        return H_NEIGHBORHOOD

    def init_meta(self, m: int) -> jnp.ndarray:
        return jnp.zeros((m,), jnp.uint32)

    # -- lookup: gather <= H bitmap-indicated cells; wait-free, bounded.

    def find_batch(self, ht, keys, active=None):
        keys = jnp.asarray(keys, dtype=jnp.uint32)
        m = BT.size(ht)
        B = keys.shape[0]
        Hn = self.neighborhood(m)
        act = BT._active_mask(B, active)
        hv = BT._hash(ht, keys)
        d = jnp.arange(Hn, dtype=jnp.int32)
        pos = jnp.mod(hv[:, None] + d[None, :], m)              # [B, Hn]
        member = (jnp.right_shift(ht.meta[hv][:, None],
                                  d[None, :].astype(jnp.uint32)) & 1) == 1
        target = (keys << 2) | E.TAG_FINAL
        hit = member & (ht.table[pos] == target[:, None]) & act[:, None]
        found = jnp.any(hit, axis=1)
        first = jnp.argmax(hit, axis=1)
        slot = jnp.where(found,
                         jnp.take_along_axis(pos, first[:, None],
                                             axis=1)[:, 0],
                         jnp.int32(-1))
        return found, slot

    # -- delete: cell -> EMPTY, clear the home bit.  No tombstones.

    def delete_batch(self, ht, keys, active=None):
        keys = jnp.asarray(keys, dtype=jnp.uint32)
        m = BT.size(ht)
        B = keys.shape[0]
        act = BT._active_mask(B, active)
        hv = BT._hash(ht, keys)
        found, slot = self.find_batch(ht, keys, act)
        leader = BT._dedup_leaders(keys, act)
        win = found & leader
        idx = jnp.where(win, slot, m)
        table = ht.table.at[idx].set(jnp.uint32(E.EMPTY), mode="drop")
        # winners hold distinct slots, so per home bucket each cleared bit
        # is distinct and a scatter-ADD of powers of two equals the OR
        d = jnp.mod(slot - hv, m).astype(jnp.uint32)
        bit = jnp.left_shift(jnp.uint32(1), d)
        clear = jnp.zeros((m,), jnp.uint32).at[
            jnp.where(win, hv, m)].add(bit, mode="drop")
        meta = ht.meta & ~clear
        ret = win.astype(jnp.int32)
        ht2 = ht._replace(table=table, meta=meta,
                          num_keys=ht.num_keys - jnp.sum(win))
        return ht2, ret

    # -- insert: in-neighborhood scatter-min claims; hop displacement for
    #    lanes whose first EMPTY lies outside, one lane per round.

    def insert_batch(self, ht, keys, active=None, claim_tombstones=True):
        # claim_tombstones is meaningless here (deletes never tombstone);
        # accepted for API uniformity.
        del claim_tombstones
        keys = jnp.asarray(keys, dtype=jnp.uint32)
        m = BT.size(ht)
        B = keys.shape[0]
        Hn = self.neighborhood(m)
        act = BT._active_mask(B, active)
        hv = BT._hash(ht, keys)
        leader = BT._dedup_leaders(keys, act)
        present, _ = self.find_batch(ht, keys, act)
        lane = jnp.arange(B, dtype=jnp.int32)
        target = (keys << 2) | E.TAG_FINAL
        doff = jnp.arange(Hn, dtype=jnp.int32)

        def near_claim(table, meta, pending):
            """One data-parallel round of in-neighborhood claims."""
            pos = jnp.mod(hv[:, None] + doff[None, :], m)       # [B, Hn]
            empty = table[pos] == jnp.uint32(E.EMPTY)
            has = jnp.any(empty, axis=1) & pending
            first = jnp.argmax(empty, axis=1)
            cand = jnp.take_along_axis(pos, first[:, None], axis=1)[:, 0]
            claim_idx = jnp.where(has, cand, m)
            claims = jnp.full((m,), B, jnp.int32).at[claim_idx].min(
                lane, mode="drop")
            won = has & (claims[cand] == lane)
            table = table.at[jnp.where(won, cand, m)].set(target,
                                                          mode="drop")
            # same home bucket => same first-EMPTY target => one winner per
            # bucket per round, so scatter-ADD of the bit equals the OR
            bit = jnp.left_shift(jnp.uint32(1), first.astype(jnp.uint32))
            setmask = jnp.zeros((m,), jnp.uint32).at[
                jnp.where(won, hv, m)].add(bit, mode="drop")
            meta = meta | setmask
            return table, meta, pending & ~won, won

        def displace_one(table, meta, b):
            """Resolve lane ``b`` whose whole neighborhood is full: claim
            the first EMPTY past the home bucket and hop it backwards by
            relocating residents within their own neighborhoods.  Returns
            (table, meta, placed_b, aborted_b)."""
            if Hn >= m:
                # the neighborhood covers the whole table, so near_claim
                # sees every EMPTY cell: reaching here means the table is
                # completely full -> ABORT (no displacement possible)
                return table, meta, jnp.bool_(False), jnp.bool_(True)
            home = hv[b]
            idx = jnp.arange(m, dtype=jnp.int32)
            dist_all = jnp.mod(idx - home, m)
            dmin = jnp.min(jnp.where(table == jnp.uint32(E.EMPTY),
                                     dist_all, m))
            no_empty = dmin >= m  # table completely full -> ABORT
            j0 = jnp.mod(home + jnp.minimum(dmin, m - 1), m)

            off = jnp.arange(1, Hn, dtype=jnp.int32)

            def hop_cond(st):
                table, meta, j, dist_j, stuck = st
                return (dist_j >= Hn) & ~stuck

            def hop_body(st):
                table, meta, j, dist_j, stuck = st
                # candidates i = j - off: all non-EMPTY (j was the first
                # EMPTY from home and dist_j >= Hn keeps them in [home, j))
                i = jnp.mod(j - off, m)                          # [Hn-1]
                rkeys = E.dec_key(table[i])
                rhome = BT._hash(ht, rkeys)
                movable = jnp.mod(j - rhome, m) < Hn
                any_mov = jnp.any(movable)
                # furthest-back movable resident maximizes progress
                osel = jnp.max(jnp.where(movable, off, 0))
                isel = jnp.mod(j - osel, m)
                moved_key = E.dec_key(table[isel])
                h_k = BT._hash(ht, moved_key[None])[0]
                old_d = jnp.mod(isel - h_k, m).astype(jnp.uint32)
                new_d = jnp.mod(j - h_k, m).astype(jnp.uint32)
                table2 = table.at[j].set(table[isel]).at[isel].set(
                    jnp.uint32(E.EMPTY))
                mword = ((meta[h_k]
                          & ~jnp.left_shift(jnp.uint32(1), old_d))
                         | jnp.left_shift(jnp.uint32(1), new_d))
                meta2 = meta.at[h_k].set(mword)
                table = jnp.where(any_mov, table2, table)
                meta = jnp.where(any_mov, meta2, meta)
                j = jnp.where(any_mov, isel, j)
                return (table, meta, j, jnp.mod(j - home, m),
                        stuck | ~any_mov)

            table, meta, j, dist_j, stuck = jax.lax.while_loop(
                hop_cond, hop_body,
                (table, meta, j0, jnp.mod(j0 - home, m), no_empty))
            ok = ~stuck
            table = jnp.where(ok, table.at[j].set(target[b]), table)
            mword = meta[home] | jnp.left_shift(
                jnp.uint32(1), dist_j.astype(jnp.uint32))
            meta = jnp.where(ok, meta.at[home].set(mword), meta)
            return table, meta, ok, ~ok

        def cond(st):
            table, meta, pending, placed, aborted = st
            return jnp.any(pending)

        def body(st):
            table, meta, pending, placed, aborted = st
            table, meta, pending, won = near_claim(table, meta, pending)
            placed = placed | won

            def with_hop(args):
                table, meta, pending, placed, aborted = args
                b = jnp.argmin(jnp.where(pending, lane, B))
                table, meta, ok, bad = displace_one(table, meta, b)
                placed = placed.at[b].set(placed[b] | ok)
                aborted = aborted.at[b].set(aborted[b] | bad)
                pending = pending.at[b].set(False)
                return table, meta, pending, placed, aborted

            # every near_claim round with an eligible lane places at least
            # one lane (the global scatter-min winner); displacement only
            # runs when NO lane can claim in-neighborhood
            need_hop = ~jnp.any(won) & jnp.any(pending)
            return jax.lax.cond(need_hop, with_hop, lambda a: a,
                                (table, meta, pending, placed, aborted))

        st0 = (ht.table, ht.meta, leader & ~present,
               jnp.zeros((B,), bool), jnp.zeros((B,), bool))
        table, meta, _, placed, aborted = jax.lax.while_loop(cond, body, st0)

        ret = _finalize_insert_ret(keys, act, leader, present, placed,
                                   aborted)
        ht2 = ht._replace(table=table, meta=meta,
                          num_keys=ht.num_keys + jnp.sum(placed))
        return ht2, ret


STRATEGIES: Dict[str, ProbeStrategy] = {
    s.name: s for s in (LinearStrategy(), RobinHoodStrategy(),
                        HopscotchStrategy())
}


def get_strategy(name: str) -> ProbeStrategy:
    try:
        return STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown probe strategy {name!r}; expected one of "
            f"{sorted(STRATEGIES)}") from None
