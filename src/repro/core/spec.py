"""Sequential specification of the dictionary (the abstract object).

``insert(v)`` returns True iff v was absent (and adds it); ``delete(v)``
returns True iff v was present (and removes it); ``lookup(v)`` returns whether
v is present.  ``insert`` may nondeterministically return ABORT without
modifying the set (Section 4: ABORTs do not affect the logical state).

Used as the oracle for linearizability checking and for validating the
batched/TPU implementations.
"""
from __future__ import annotations

from typing import Iterable, List, Sequence, Set, Tuple

# Operation codes shared across the package.
OP_LOOKUP = 0
OP_INSERT = 1
OP_DELETE = 2
OP_NONE = -1

# Return codes.
RET_FALSE = 0
RET_TRUE = 1
RET_ABORT = 2
RET_PENDING = -1

OP_NAMES = {OP_LOOKUP: "lookup", OP_INSERT: "insert", OP_DELETE: "delete"}
RET_NAMES = {RET_FALSE: "false", RET_TRUE: "true", RET_ABORT: "ABORT",
             RET_PENDING: "pending"}


def step_spec(state: Set[int], op: int, key: int) -> Tuple[Set[int], int]:
    """Apply one operation to the abstract set; return (state', ret)."""
    if op == OP_LOOKUP:
        return state, (RET_TRUE if key in state else RET_FALSE)
    if op == OP_INSERT:
        if key in state:
            return state, RET_FALSE
        return state | {key}, RET_TRUE
    if op == OP_DELETE:
        if key in state:
            return state - {key}, RET_TRUE
        return state, RET_FALSE
    raise ValueError(f"bad op {op}")


def apply_sequential(ops: Iterable[Tuple[int, int]],
                     initial: Set[int] | None = None) -> Tuple[Set[int], List[int]]:
    """Run a sequence of (op, key) through the spec; returns final state and
    the list of return codes."""
    state = set(initial or ())
    rets: List[int] = []
    for op, key in ops:
        state, r = step_spec(state, op, key)
        rets.append(r)
    return state, rets


def legal_next(state_present: bool, op: int, ret: int) -> Tuple[bool, bool]:
    """Single-key spec automaton: given presence bit, is (op, ret) legal, and
    what is the next presence bit?  ABORTing inserts are legal in any state
    and do not change it."""
    if op == OP_INSERT and ret == RET_ABORT:
        return True, state_present
    if op == OP_LOOKUP:
        return (ret == (RET_TRUE if state_present else RET_FALSE)), state_present
    if op == OP_INSERT:
        if state_present:
            return ret == RET_FALSE, True
        return ret == RET_TRUE, True
    if op == OP_DELETE:
        if state_present:
            return ret == RET_TRUE, False
        return ret == RET_FALSE, False
    raise ValueError(f"bad op {op}")
