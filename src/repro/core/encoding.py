"""Cell encoding for the lock-free linear-probing hash table.

Implements the bit-level layout from the paper (Section 4.2):

* Each cell stores a *tagged key* ``<v, tag>`` with ``tag in {tentative, final,
  revalidate}``, or one of four key-less states ``EMPTY / TOMBSTONE / DELETED /
  COLLIDED``.  Using the two tag bits, **one reserved sentinel key value** is
  sufficient to encode the four key-less states, giving ``ceil(log(U+1)) + 2``
  bits per cell for the LL/SC version (Theorem 1).
* The CAS version adds a *marked* state ``<<v, j>, marked>`` carrying the index
  of the cell (or process) that claimed provisional ownership — an extra
  ``min(ceil(log m), ceil(log n))`` bits.

Concretely we pack ``cell = (key << 2) | tag`` into a uint32 (keys are at most
28 bits in this build; the key domain size ``U`` is configurable for the space
accounting below, which is analytic and independent of the carrier dtype).
The CAS owner field is carried in a parallel int32 array by the simulator; the
logical cell is the pair, and all simulated atomic events cover both words
(see DESIGN.md §2 — this is a simulation artifact, not an algorithm change).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Tags (2 bits).
TAG_TENTATIVE = 0
TAG_FINAL = 1
TAG_REVALIDATE = 2
TAG_SPECIAL = 3  # key == RESERVED: one of the 4 key-less states.
                 # key != RESERVED: CAS-version ``marked`` state.

KEY_BITS = 28
RESERVED_KEY = (1 << KEY_BITS) - 1  # sentinel key value
MAX_KEY = RESERVED_KEY - 1          # usable key domain [0, MAX_KEY]

# Key-less states: <RESERVED, tag> reinterprets the tag bits as a selector.
# EMPTY must be tag 0 so that a zero-filled... (we keep explicit constants).
EMPTY = (RESERVED_KEY << 2) | 0
TOMBSTONE = (RESERVED_KEY << 2) | 1
DELETED = (RESERVED_KEY << 2) | 2
COLLIDED = (RESERVED_KEY << 2) | 3

NO_OWNER = -1


def enc(key, tag):
    """Encode ``<key, tag>`` into a uint32 cell word."""
    return jnp.uint32((jnp.uint32(key) << 2) | jnp.uint32(tag))


def enc_tentative(key):
    return enc(key, TAG_TENTATIVE)


def enc_final(key):
    return enc(key, TAG_FINAL)


def enc_revalidate(key):
    return enc(key, TAG_REVALIDATE)


def enc_marked(key):
    """CAS-version marked word; the owner index lives in the parallel array."""
    return enc(key, TAG_SPECIAL)


def dec_key(cell):
    """The key field of a cell word (== RESERVED_KEY for key-less states)."""
    return jnp.uint32(cell) >> 2


def dec_tag(cell):
    return jnp.uint32(cell) & 3


def val(cell):
    """The paper's ``val(x)``: the key stored in ``x`` or RESERVED_KEY (⊥)."""
    return dec_key(cell)


def has_key(cell, key):
    """Does this cell *contain the key* ``key`` (tentative/final/revalidate/
    marked — Section 5.1's definition)?"""
    return dec_key(cell) == jnp.uint32(key)


def is_available(cell):
    """EMPTY or TOMBSTONE — claimable by an insert (Algorithm 3, line 43)."""
    c = jnp.uint32(cell)
    return (c == jnp.uint32(EMPTY)) | (c == jnp.uint32(TOMBSTONE))


def is_marked(cell):
    c = jnp.uint32(cell)
    return (dec_tag(c) == TAG_SPECIAL) & (dec_key(c) != jnp.uint32(RESERVED_KEY))


def restart(cell):
    """The paper's ``restart(x)``: owner should re-validate — true iff
    ``x == <v, revalidate>`` or (CAS) ``x == <<v,*>, marked>``."""
    c = jnp.uint32(cell)
    is_key = dec_key(c) != jnp.uint32(RESERVED_KEY)
    tag = dec_tag(c)
    return is_key & ((tag == TAG_REVALIDATE) | (tag == TAG_SPECIAL))


# ---------------------------------------------------------------------------
# Space accounting — Theorem 1 / Table 1.

class CellSize(NamedTuple):
    key_bits: int        # ceil(log2(U + 1)) — key + one reserved sentinel
    tag_bits: int        # always 2
    owner_bits: int      # 0 for LL/SC; min(ceil(log m), ceil(log n)) for CAS
    total: int


def _clog2(x: int) -> int:
    return max(1, math.ceil(math.log2(x)))


def cell_size_llsc(U: int) -> CellSize:
    """LL/SC version: ceil(log(U+1)) + 2 bits (Theorem 1)."""
    kb = _clog2(U + 1)
    return CellSize(kb, 2, 0, kb + 2)


def cell_size_cas(U: int, n: int, m: int) -> CellSize:
    """CAS version: + min(ceil(log m), ceil(log n)) owner bits (Theorem 1)."""
    kb = _clog2(U + 1)
    ob = min(_clog2(m), _clog2(n))
    return CellSize(kb, 2, ob, kb + 2 + ob)


def table_bits_llsc(U: int, m: int) -> int:
    """Total table footprint, LL/SC version: m * (ceil(log(U+1)) + 2)."""
    return m * cell_size_llsc(U).total


def table_bits_cas(U: int, n: int, m: int) -> int:
    return m * cell_size_cas(U, n, m).total


# Prior-work cell sizes (Table 1), for the space benchmark.
def cell_size_gao(U: int) -> int:
    """[7,14]: tombstones, no reuse: ceil(log U + 2) bits."""
    return _clog2(U) + 2


def cell_size_robinhood(U: int) -> int:
    """[3]: 2 * ceil(log U + 1) + 2 bits (two keys per cell)."""
    return 2 * (_clog2(U) + 1) + 2


def cell_size_shun_blelloch(U: int) -> int:
    """[20]: ceil(log U + 1) bits (phase-concurrent only)."""
    return _clog2(U) + 1


def cell_size_purcell_harris_lower_bound(U: int, timestamp_bits: int = 64) -> int:
    """[18]: probe bounds + unbounded timestamps; any finite run needs at
    least key + probe-bound + 2 timestamps of ``timestamp_bits``."""
    return _clog2(U) + 2 * timestamp_bits + 8
