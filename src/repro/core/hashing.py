"""Hash functions for the linear-probing table.

Multiply-shift hashing (Dietzfelbinger et al.): ``h(v) = (v * A mod 2^32) >>
(32 - k)`` for a table of size ``m = 2^k`` and odd seed-derived multiplier
``A``.  This is the standard cheap family whose behaviour on random keys
matches the uniform-hashing assumption of Knuth's O(x^2) analysis closely
enough for the step-complexity experiments; the algorithm itself is oblivious
to the hash family.

For non-power-of-two ``m`` we fall back to Fibonacci multiplicative hashing
followed by a modulo; the simulator supports arbitrary ``m`` (the paper's
modular arithmetic wraps at m).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FIB32 = 2654435769  # 2^32 / phi, odd


def derive_multiplier(seed: int) -> int:
    """Derive an odd 32-bit multiplier from a seed (splitmix-style)."""
    z = (seed + 0x9E3779B9) & 0xFFFFFFFF
    z = (z ^ (z >> 16)) * 0x85EBCA6B & 0xFFFFFFFF
    z = (z ^ (z >> 13)) * 0xC2B2AE35 & 0xFFFFFFFF
    z = z ^ (z >> 16)
    return (z | 1) & 0xFFFFFFFF


def is_pow2(m: int) -> bool:
    return m > 0 and (m & (m - 1)) == 0


def hash_keys(keys, m: int, seed: int = 0):
    """Vectorized h(v) in [0, m). ``keys``: uint32 array or scalar."""
    A = jnp.uint32(derive_multiplier(seed))
    x = jnp.uint32(keys) * A
    if is_pow2(m):
        k = int(np.log2(m))
        if k == 0:
            return jnp.zeros_like(x, dtype=jnp.int32)
        return (x >> jnp.uint32(32 - k)).astype(jnp.int32)
    # general m: multiply-shift to 16 bits then scale (avoids 64-bit ops)
    hi = (x >> 16).astype(jnp.uint32)
    return ((hi * jnp.uint32(m)) >> 16).astype(jnp.int32)


def probe_distance(idx, start, m: int):
    """Distance of ``idx`` from ``start`` along the probe sequence (mod m) —
    the paper's ``i - h(v)`` with wraparound."""
    d = jnp.int32(idx) - jnp.int32(start)
    return jnp.where(d < 0, d + m, d)
