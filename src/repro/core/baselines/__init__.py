"""Baselines the paper compares against (Table 1)."""
