"""Baseline: tombstones WITHOUT reuse — the [7,14] design point.

Gao-Groote-Hesselink (2005) and Maier-Sanders-Dementiev (2019) mark deleted
cells with tombstones that inserts may NOT claim (or may claim only for the
same key).  This keeps synchronization simple and needs no per-cell metadata
beyond the paper's two bits, but tombstones accumulate: table *occupancy*
(keys + tombstones) grows monotonically with churn, and once it reaches m the
table must be rebuilt even though the number of live keys is far below m.

The paper's contribution is exactly removing this rebuild requirement while
keeping bounded metadata.  ``bench_reuse`` measures the difference: sustained
insert/delete churn at a fixed live-key working set.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core import batched as BT
from repro.core import encoding as E

create = BT.create
lookup_batch = BT.lookup_batch
delete_batch = BT.delete_batch


def insert_batch(ht: BT.HashTable, keys,
                 active=None) -> Tuple[BT.HashTable, jnp.ndarray]:
    """Insert claiming only EMPTY cells (no tombstone reuse)."""
    return BT.insert_batch(ht, keys, active=active, claim_tombstones=False)


def needs_rebuild(ht: BT.HashTable, slack: float = 0.95) -> jnp.ndarray:
    """True when occupancy (keys + tombstones) nears capacity; at that point
    inserts start ABORTing even if few live keys remain."""
    return BT.occupancy(ht) >= slack


def rebuild(ht: BT.HashTable, new_m: int | None = None) -> BT.HashTable:
    """Rebuild into a fresh table (drops tombstones); this is the periodic
    cost the paper's reuse scheme avoids."""
    return BT.rebuild(ht, new_m or BT.size(ht))
