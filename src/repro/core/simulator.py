"""Faithful executable specification of the paper's Algorithms 1-6.

Every process's program (lookup / insert / delete, in both the LL/SC and the
CAS variant) is hand-compiled into a *memory-operation-site* state machine:
each site performs exactly one shared-memory primitive (the paper's model —
"each step consists of some local computation, followed by a single primitive
operation on the shared memory"), and the post-logic of the site folds all
local computation up to the next primitive.

The interpreter is written in pure ``jax.numpy`` select-style transitions, so
the same code runs eagerly (oracle / debugging) and under ``jit`` + ``vmap``
(thousands of random schedules in parallel — the data-parallel way a SIMD
machine executes an asynchronous algorithm).

Site map (pseudocode line numbers refer to the paper):

  FS_READ        Alg.1 l.2/11    Read(table[i])        forward scan
  BS_READ        Alg.1 l.16      Read(table[i])        backward scan
  VC_MOD         Alg.4 l.93      Modify(i, val -> <v,revalidate>)
  VC_READ        Alg.4 l.94      plain read table[i]
  TD_MOD_TOMB    Alg.4 l.86      Modify(i, <v,final> -> TOMBSTONE)
  TD_MOD_DEL     Alg.4 l.88      Modify(i, val -> DELETED)
  TD_READ        Alg.4 l.89      Read(table[i])
  I_READ_CLAIM   Alg.3 l.41/46   Read(table[j])        claim loop
  I_MOD_CLAIM    Alg.3 l.43      Modify(j, val -> <v,tentative>)
  I_READ_SCAN    Alg.3 l.48/65   Read(table[i])        duplicate scan
  I_READ_OWN     Alg.3 l.66      Read(table[j])
  I_MOD_FINAL    Alg.3 l.67      Modify(j, cur -> <v,final>)
  I_MOD_RESTART  Alg.3 l.58/69   Modify(j, cur -> <v,tentative>)
  I_READ_OWN2    Alg.3 l.57      Read(table[j])
  DC_READ        Alg.4 l.76      Read(table[j])        del_copy
  DC_MOD_REVAL   Alg.4 l.78      Modify(j, <v,reval> -> <v,tentative>)
  DC_READ2       Alg.4 l.79      Read(table[j])
  DC_MOD_TOMB    Alg.4 l.80      Modify(j, val -> TOMBSTONE)
  -- LL/SC del_other_copy (Alg.5):
  DOC_READ_OWN   l.102           plain read table[j]
  DOC_SC         l.104           SC(table[i], COLLIDED)
  DOC_READ_I     l.105           plain read table[i]
  -- CAS del_other_copy (Alg.6):
  DOC_CAS_MARK   l.116           CAS(table[i], val -> <<v,j>,marked>)
  DOC_READ_I2    l.117           plain read table[i]
  DOC_READ_OWN_C l.120           plain read table[j]
  DOC_CAS_COLL   l.122           CAS(table[i], marked -> COLLIDED)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding as E
from repro.core import hashing as H
from repro.core.spec import (OP_DELETE, OP_INSERT, OP_LOOKUP, OP_NONE,
                             RET_ABORT, RET_FALSE, RET_PENDING, RET_TRUE)

# ---------------------------------------------------------------------------
# Sites.
FS_READ = 0
BS_READ = 1
VC_MOD = 2
VC_READ = 3
TD_MOD_TOMB = 4
TD_MOD_DEL = 5
TD_READ = 6
I_READ_CLAIM = 7
I_MOD_CLAIM = 8
I_READ_SCAN = 9
I_READ_OWN = 10
I_MOD_FINAL = 11
I_MOD_RESTART = 12
I_READ_OWN2 = 13
DC_READ = 14
DC_MOD_REVAL = 15
DC_READ2 = 16
DC_MOD_TOMB = 17
DOC_READ_OWN = 18
DOC_SC = 19
DOC_READ_I = 20
DOC_CAS_MARK = 21
DOC_READ_I2 = 22
DOC_READ_OWN_C = 23
DOC_CAS_COLL = 24
HALT = 25
NUM_SITES = 26

CONT_FS = 0
CONT_BS = 1

# memop kinds
MEM_NONE = 0
MEM_READ_KW = 1    # the paper's Read keyword: LL (llsc) / plain read (cas)
MEM_MODIFY = 2     # the paper's Modify keyword: SC (llsc) / CAS (cas)
MEM_PLAIN_READ = 3
MEM_SC = 4         # explicit SC (Alg.5 l.104)
MEM_CAS = 5        # explicit CAS (Alg.6)

MODE_LLSC = "llsc"
MODE_CAS = "cas"


class Regs(NamedTuple):
    pc: jnp.ndarray        # int32[P]
    opidx: jnp.ndarray     # int32[P]
    v: jnp.ndarray         # uint32[P]
    hv: jnp.ndarray        # int32[P]
    i: jnp.ndarray         # int32[P]
    j: jnp.ndarray         # int32[P]
    val: jnp.ndarray       # uint32[P]
    val_o: jnp.ndarray     # int32[P]
    cur: jnp.ndarray       # uint32[P]
    cur_o: jnp.ndarray     # int32[P]
    cont: jnp.ndarray      # int32[P]
    ll_cell: jnp.ndarray   # int32[P]
    ll_ver: jnp.ndarray    # int32[P]
    fresh: jnp.ndarray     # int32[P]
    op: jnp.ndarray        # int32[P] current op type


class SimState(NamedTuple):
    table: jnp.ndarray     # uint32[m]
    owner: jnp.ndarray     # int32[m]   (CAS marked owner; NO_OWNER otherwise)
    ver: jnp.ndarray       # int32[m]   (write counter, simulates LL/SC validity)
    regs: Regs
    results: jnp.ndarray   # int32[P,K]
    t_inv: jnp.ndarray     # int32[P,K]
    t_rsp: jnp.ndarray     # int32[P,K]
    steps: jnp.ndarray     # int32[P,K] memops consumed per op
    t: jnp.ndarray         # int32 global event counter
    pair_ok: jnp.ndarray   # bool — LL/SC proper-pairing assertion
    inv_ok: jnp.ndarray    # bool — Lemma 4 + Prop 3 monitors (if enabled)


# ---------------------------------------------------------------------------
# Helpers building register updates (scalar view of one process).

class PRegs(NamedTuple):
    pc: jnp.ndarray
    opidx: jnp.ndarray
    v: jnp.ndarray
    hv: jnp.ndarray
    i: jnp.ndarray
    j: jnp.ndarray
    val: jnp.ndarray
    val_o: jnp.ndarray
    cur: jnp.ndarray
    cur_o: jnp.ndarray
    cont: jnp.ndarray
    ll_cell: jnp.ndarray
    ll_ver: jnp.ndarray
    fresh: jnp.ndarray
    op: jnp.ndarray
    # transition outputs:
    complete: jnp.ndarray  # int32 0/1
    retval: jnp.ndarray    # int32


def _mk(r: PRegs, **kw) -> PRegs:
    return r._replace(**{k: _cast(r, k, v) for k, v in kw.items()})


def _cast(r, k, v):
    ref = getattr(r, k)
    return jnp.asarray(v).astype(ref.dtype)


def _where_regs(c, a: PRegs, b: PRegs) -> PRegs:
    return PRegs(*[jnp.where(c, x, y) for x, y in zip(a, b)])


def _select_regs(cs, rs, default: PRegs) -> PRegs:
    out = default
    # apply in reverse so earlier conditions win
    for c, r in zip(reversed(cs), reversed(rs)):
        out = _where_regs(c, r, out)
    return out


def _complete(r: PRegs, ret) -> PRegs:
    return _mk(r, complete=1, retval=ret, pc=HALT)


# --- scan-resumption helpers -------------------------------------------------

def _enter_bs(r: PRegs, idx) -> PRegs:
    return _mk(r, cont=CONT_BS, i=idx, pc=BS_READ)


def _after_bs(r: PRegs) -> PRegs:
    """backward_scan returned ⊥ (back at h(v)) — dispatch per op type."""
    is_ins = r.op == OP_INSERT
    ins = _mk(r, j=r.hv, pc=I_READ_CLAIM)
    done = _complete(r, RET_FALSE)
    return _where_regs(is_ins, ins, done)


def _resume_scan(r: PRegs, m: int) -> PRegs:
    """Helper (validate_copy/try_delete) said "not found, keep scanning"."""
    # forward: i+=1; if i==hv: break -> bs starts at i-1 (mod m)
    i2 = jnp.mod(r.i + 1, m)
    fs_wrap = _enter_bs(r, jnp.mod(r.hv - 1 + m, m))
    fs_go = _mk(r, i=i2, pc=FS_READ)
    fs = _where_regs(i2 == r.hv, fs_wrap, fs_go)
    # backward: if i==hv: return ⊥; else i-=1
    bs_done = _after_bs(r)
    bs_go = _mk(r, i=jnp.mod(r.i - 1 + m, m), pc=BS_READ)
    bs = _where_regs(r.i == r.hv, bs_done, bs_go)
    return _where_regs(r.cont == CONT_FS, fs, bs)


def _scan_found_true(r: PRegs) -> PRegs:
    """forward/backward scan "found the key" (validate_copy true):
    lookup returns true, insert returns false."""
    ret = jnp.where(r.op == OP_LOOKUP, RET_TRUE, RET_FALSE)
    return _complete(r, ret)


def _vc_entry(r: PRegs, rval, ro) -> PRegs:
    """validate_copy(v, val, i) local prefix (Alg.4 l.92): called with the
    freshly read val; caller is insert/lookup during a scan."""
    fin = rval == E.enc_final(r.v)
    rev = rval == E.enc_revalidate(r.v)
    hit = fin | rev
    go_mod = _mk(r, val=rval, val_o=ro, pc=VC_MOD)
    return _where_regs(hit, _scan_found_true(r), go_mod)


def _td_entry(r: PRegs, rval, ro) -> PRegs:
    """try_delete local prefix (Alg.4 l.84-88), val freshly read, contains v."""
    fin = rval == E.enc_final(r.v)
    tomb = _mk(r, val=rval, val_o=ro, pc=TD_MOD_TOMB)
    dele = _mk(r, val=rval, val_o=ro, pc=TD_MOD_DEL)
    return _where_regs(fin, tomb, dele)


def _advance_dedup(r: PRegs, m: int) -> PRegs:
    """dedup scan: i+=1; full cycle -> finalize own copy (l.63-66)."""
    i2 = jnp.mod(r.i + 1, m)
    own = _mk(r, pc=I_READ_OWN)
    go = _mk(r, i=i2, pc=I_READ_SCAN)
    return _where_regs(i2 == r.hv, own, go)


# ---------------------------------------------------------------------------
# The per-site memop specification.

def memop_spec(r: PRegs, mode: str):
    """Return (kind, cell, oldv, oldo, newv, newo) for the process's pc."""
    pc = r.pc
    u32 = lambda x: jnp.uint32(x)
    kinds = jnp.array([
        MEM_READ_KW,   # FS_READ
        MEM_READ_KW,   # BS_READ
        MEM_MODIFY,    # VC_MOD
        MEM_PLAIN_READ,# VC_READ
        MEM_MODIFY,    # TD_MOD_TOMB
        MEM_MODIFY,    # TD_MOD_DEL
        MEM_READ_KW,   # TD_READ
        MEM_READ_KW,   # I_READ_CLAIM
        MEM_MODIFY,    # I_MOD_CLAIM
        MEM_READ_KW,   # I_READ_SCAN
        MEM_READ_KW,   # I_READ_OWN
        MEM_MODIFY,    # I_MOD_FINAL
        MEM_MODIFY,    # I_MOD_RESTART
        MEM_READ_KW,   # I_READ_OWN2
        MEM_READ_KW,   # DC_READ
        MEM_MODIFY,    # DC_MOD_REVAL
        MEM_READ_KW,   # DC_READ2
        MEM_MODIFY,    # DC_MOD_TOMB
        MEM_PLAIN_READ,# DOC_READ_OWN
        MEM_SC,        # DOC_SC
        MEM_PLAIN_READ,# DOC_READ_I
        MEM_CAS,       # DOC_CAS_MARK
        MEM_PLAIN_READ,# DOC_READ_I2
        MEM_PLAIN_READ,# DOC_READ_OWN_C
        MEM_CAS,       # DOC_CAS_COLL
        MEM_NONE,      # HALT
    ], dtype=jnp.int32)
    kind = kinds[pc]

    # cell: sites on table[i] vs table[j]
    on_j = jnp.isin(pc, jnp.array([I_READ_CLAIM, I_MOD_CLAIM, I_READ_OWN,
                                   I_MOD_FINAL, I_MOD_RESTART, I_READ_OWN2,
                                   DC_READ, DC_MOD_REVAL, DC_READ2,
                                   DC_MOD_TOMB, DOC_READ_OWN, DOC_READ_OWN_C]))
    cell = jnp.where(on_j, r.j, r.i)

    # old value for Modify/CAS sites: val-based or cur-based
    old_from_cur = jnp.isin(pc, jnp.array([I_MOD_FINAL, I_MOD_RESTART]))
    oldv = jnp.where(old_from_cur, r.cur, r.val)
    oldo = jnp.where(old_from_cur, r.cur_o, r.val_o)
    # DOC_CAS_COLL: old = <<v,j>,marked>
    oldv = jnp.where(pc == DOC_CAS_COLL, E.enc_marked(r.v), oldv)
    oldo = jnp.where(pc == DOC_CAS_COLL, r.j, oldo)

    # new value per site
    newv = u32(E.EMPTY)
    newv = jnp.where(pc == VC_MOD, E.enc_revalidate(r.v), newv)
    newv = jnp.where(pc == TD_MOD_TOMB, u32(E.TOMBSTONE), newv)
    newv = jnp.where(pc == TD_MOD_DEL, u32(E.DELETED), newv)
    newv = jnp.where(pc == I_MOD_CLAIM, E.enc_tentative(r.v), newv)
    newv = jnp.where(pc == I_MOD_FINAL, E.enc_final(r.v), newv)
    newv = jnp.where(pc == I_MOD_RESTART, E.enc_tentative(r.v), newv)
    newv = jnp.where(pc == DC_MOD_REVAL, E.enc_tentative(r.v), newv)
    newv = jnp.where(pc == DC_MOD_TOMB, u32(E.TOMBSTONE), newv)
    newv = jnp.where(pc == DOC_SC, u32(E.COLLIDED), newv)
    newv = jnp.where(pc == DOC_CAS_MARK, E.enc_marked(r.v), newv)
    newv = jnp.where(pc == DOC_CAS_COLL, u32(E.COLLIDED), newv)
    newo = jnp.where(pc == DOC_CAS_MARK, r.j, jnp.int32(E.NO_OWNER))
    return kind, cell, oldv, oldo, newv, newo


def exec_memop(table, owner, ver, r: PRegs, kind, cell, oldv, oldo, newv,
               newo, mode: str):
    """Execute the memory primitive; returns (rval, ro, success, table, owner,
    ver, ll_cell, ll_ver, pair_ok_delta)."""
    cur_v = table[cell]
    cur_o = owner[cell]
    cur_ver = ver[cell]

    is_read_kw = kind == MEM_READ_KW
    is_plain = kind == MEM_PLAIN_READ
    is_mod = kind == MEM_MODIFY
    is_sc_site = kind == MEM_SC
    is_cas_site = kind == MEM_CAS

    if mode == MODE_LLSC:
        # Read keyword = LL; Modify keyword = SC; explicit SC site too.
        do_ll = is_read_kw
        do_sc = is_mod | is_sc_site
        do_cas = is_cas_site  # never true in llsc programs
    else:
        do_ll = jnp.zeros_like(is_read_kw)
        do_sc = is_sc_site   # never true in cas programs
        do_cas = is_mod | is_cas_site

    # LL: record reservation
    ll_cell = jnp.where(do_ll, cell, r.ll_cell)
    ll_ver = jnp.where(do_ll, cur_ver, r.ll_ver)

    # SC: succeeds iff reservation matches this cell and version unchanged
    sc_paired = r.ll_cell == cell
    sc_ok = do_sc & sc_paired & (r.ll_ver == cur_ver)
    pair_ok = ~(do_sc & ~sc_paired)  # proper-pairing assertion

    # CAS: value (and owner for marked words) comparison
    val_eq = cur_v == oldv
    own_eq = jnp.where(E.is_marked(oldv), cur_o == oldo, True)
    cas_ok = do_cas & val_eq & own_eq

    success = sc_ok | cas_ok
    write = success
    table = table.at[cell].set(jnp.where(write, newv, cur_v))
    owner = owner.at[cell].set(jnp.where(write, newo, cur_o))
    ver = ver.at[cell].set(jnp.where(write, cur_ver + 1, cur_ver))

    # SC consumes the reservation (success or failure)
    ll_cell = jnp.where(do_sc, jnp.int32(-1), ll_cell)

    did_mem = kind != MEM_NONE
    return cur_v, cur_o, success, table, owner, ver, ll_cell, ll_ver, pair_ok, did_mem


# ---------------------------------------------------------------------------
# Per-site post-transitions.

def make_post(mode: str, m: int):
    """Build the list of post-transition functions, one per site.

    Each takes (r: PRegs, rval, ro, success) -> PRegs (with complete/retval
    possibly set)."""

    def fs_read(r, rval, ro, success):
        empty = rval == jnp.uint32(E.EMPTY)
        haskey = E.dec_key(rval) == r.v
        # empty: exit forward scan (Alg.1 l.12-13)
        idx = jnp.where(r.i == r.hv, r.i, jnp.mod(r.i - 1 + m, m))
        exit_fs = _enter_bs(r, idx)
        # found key: dispatch helper
        is_del = r.op == OP_DELETE
        helper = _where_regs(is_del, _td_entry(r, rval, ro),
                             _vc_entry(r, rval, ro))
        # else advance (l.9-11); wrap -> break -> bs starts at i-1 == hv-1
        i2 = jnp.mod(r.i + 1, m)
        wrap = _enter_bs(r, jnp.mod(r.hv - 1 + m, m))
        adv = _where_regs(i2 == r.hv, wrap, _mk(r, i=i2, pc=FS_READ))
        return _select_regs([empty, haskey], [exit_fs, helper], adv)

    def bs_read(r, rval, ro, success):
        haskey = E.dec_key(rval) == r.v
        is_del = r.op == OP_DELETE
        helper = _where_regs(is_del, _td_entry(r, rval, ro),
                             _vc_entry(r, rval, ro))
        at_start = r.i == r.hv
        adv = _where_regs(at_start, _after_bs(r),
                          _mk(r, i=jnp.mod(r.i - 1 + m, m), pc=BS_READ))
        return _where_regs(haskey, helper, adv)

    def vc_mod(r, rval, ro, success):
        # Alg.4 l.93: success -> validate_copy true
        return _where_regs(success, _scan_found_true(r),
                           _mk(r, pc=VC_READ))

    def vc_read(r, rval, ro, success):
        # Alg.4 l.94-96
        haskey = E.dec_key(rval) == r.v
        return _where_regs(haskey, _scan_found_true(r), _resume_scan(r, m))

    def td_mod_tomb(r, rval, ro, success):
        # Alg.4 l.86: try_delete returns Modify(...) result; delete returns it
        return _complete(r, jnp.where(success, RET_TRUE, RET_FALSE))

    def td_mod_del(r, rval, ro, success):
        return _where_regs(success, _complete(r, RET_TRUE),
                           _mk(r, pc=TD_READ))

    def td_read(r, rval, ro, success):
        haskey = E.dec_key(rval) == r.v
        return _where_regs(haskey, _td_entry(r, rval, ro), _resume_scan(r, m))

    def i_read_claim(r, rval, ro, success):
        avail = E.is_available(rval)
        claim = _mk(r, val=rval, val_o=ro, pc=I_MOD_CLAIM)
        j2 = jnp.mod(r.j + 1, m)
        abort = _complete(r, RET_ABORT)
        nxt = _where_regs(j2 == r.hv, abort, _mk(r, j=j2, pc=I_READ_CLAIM))
        return _where_regs(avail, claim, nxt)

    def i_mod_claim(r, rval, ro, success):
        scan = _mk(r, i=r.hv, pc=I_READ_SCAN)
        j2 = jnp.mod(r.j + 1, m)
        abort = _complete(r, RET_ABORT)
        nxt = _where_regs(j2 == r.hv, abort, _mk(r, j=j2, pc=I_READ_CLAIM))
        return _where_regs(success, scan, nxt)

    def i_read_scan(r, rval, ro, success):
        empty = rval == jnp.uint32(E.EMPTY)
        own_cell = r.i == r.j
        haskey = E.dec_key(rval) == r.v
        relevant = ~own_cell & haskey
        to_own = _mk(r, pc=I_READ_OWN)

        closer = (H.probe_distance(r.i, r.hv, m)
                  < H.probe_distance(r.j, r.hv, m))
        is_final = rval == E.enc_final(r.v)
        # l.51-53: other copy earlier or final -> del_copy(v, j)
        give_up = _mk(r, pc=DC_READ)
        is_reval = rval == E.enc_revalidate(r.v)
        # l.55: val != revalidate -> del_other_copy
        if mode == MODE_LLSC:
            doc = _mk(r, val=rval, val_o=ro, pc=DOC_READ_OWN)
        else:
            marked_v = E.is_marked(rval) & (E.dec_key(rval) == r.v)
            other_mark = marked_v & (ro != r.j)   # l.114: return true
            own_mark = marked_v & (ro == r.j)
            go_own = _mk(r, val=rval, val_o=ro, pc=DOC_READ_OWN_C)
            go_cas = _mk(r, val=rval, val_o=ro, pc=DOC_CAS_MARK)
            doc = _select_regs([other_mark, own_mark],
                               [_advance_dedup(r, m), go_own], go_cas)
        dup = _select_regs(
            [closer | is_final, ~is_reval],
            [give_up, doc],
            _advance_dedup(r, m))
        return _select_regs([empty, relevant], [to_own, dup],
                            _advance_dedup(r, m))

    def i_read_own(r, rval, ro, success):
        # Alg.3 l.66
        tent = rval == E.enc_tentative(r.v)
        rst = E.restart(rval) & (E.dec_key(rval) == r.v)
        fin = _mk(r, cur=rval, cur_o=ro, pc=I_MOD_FINAL)
        restart_ = _mk(r, cur=rval, cur_o=ro, pc=I_MOD_RESTART)
        dc = _mk(r, pc=DC_READ)
        return _select_regs([tent, rst], [fin, restart_], dc)

    def i_mod_final(r, rval, ro, success):
        # l.67-68; on failure fall to l.69 with stale cur (tentative) ->
        # restart(cur) false -> del_copy (l.71)
        return _where_regs(success, _complete(r, RET_TRUE), _mk(r, pc=DC_READ))

    def i_mod_restart(r, rval, ro, success):
        rescan = _mk(r, i=r.hv, pc=I_READ_SCAN)
        return _where_regs(success, rescan, _mk(r, pc=DC_READ))

    def i_read_own2(r, rval, ro, success):
        # Alg.3 l.57-58
        rst = E.restart(rval) & (E.dec_key(rval) == r.v)
        return _where_regs(rst, _mk(r, cur=rval, cur_o=ro, pc=I_MOD_RESTART),
                           _mk(r, pc=DC_READ))

    def dc_read(r, rval, ro, success):
        rev = rval == E.enc_revalidate(r.v)
        return _where_regs(rev, _mk(r, val=rval, val_o=ro, pc=DC_MOD_REVAL),
                           _mk(r, val=rval, val_o=ro, pc=DC_MOD_TOMB))

    def dc_mod_reval(r, rval, ro, success):
        rescan = _mk(r, i=r.hv, pc=I_READ_SCAN)  # del_copy returned ⊥
        return _where_regs(success, rescan, _mk(r, pc=DC_READ2))

    def dc_read2(r, rval, ro, success):
        return _mk(r, val=rval, val_o=ro, pc=DC_MOD_TOMB)

    def dc_mod_tomb(r, rval, ro, success):
        was_deleted = r.val == jnp.uint32(E.DELETED)
        done = _complete(r, jnp.where(was_deleted, RET_TRUE, RET_FALSE))
        return _where_regs(success, done, _mk(r, pc=DC_READ))

    # ---- LL/SC del_other_copy ----
    def doc_read_own(r, rval, ro, success):
        tent = rval == E.enc_tentative(r.v)
        return _where_regs(tent, _mk(r, cur=rval, cur_o=ro, pc=DOC_SC),
                           _mk(r, pc=I_READ_OWN2))  # return false -> l.56-57

    def doc_sc(r, rval, ro, success):
        return _where_regs(success, _advance_dedup(r, m),
                           _mk(r, pc=DOC_READ_I))

    def doc_read_i(r, rval, ro, success):
        fin = rval == E.enc_final(r.v)
        return _where_regs(fin, _mk(r, pc=I_READ_OWN2), _advance_dedup(r, m))

    # ---- CAS del_other_copy ----
    def doc_cas_mark(r, rval, ro, success):
        return _where_regs(success, _mk(r, pc=DOC_READ_OWN_C),
                           _mk(r, pc=DOC_READ_I2))

    def doc_read_i2(r, rval, ro, success):
        fin = rval == E.enc_final(r.v)
        return _where_regs(fin, _mk(r, pc=I_READ_OWN2), _advance_dedup(r, m))

    def doc_read_own_c(r, rval, ro, success):
        tent = rval == E.enc_tentative(r.v)
        return _where_regs(tent, _mk(r, cur=rval, cur_o=ro, pc=DOC_CAS_COLL),
                           _mk(r, pc=I_READ_OWN2))

    def doc_cas_coll(r, rval, ro, success):
        # l.122-123: CAS result ignored; return true
        return _advance_dedup(r, m)

    def halt(r, rval, ro, success):
        return r

    return [fs_read, bs_read, vc_mod, vc_read, td_mod_tomb, td_mod_del,
            td_read, i_read_claim, i_mod_claim, i_read_scan, i_read_own,
            i_mod_final, i_mod_restart, i_read_own2, dc_read, dc_mod_reval,
            dc_read2, dc_mod_tomb, doc_read_own, doc_sc, doc_read_i,
            doc_cas_mark, doc_read_i2, doc_read_own_c, doc_cas_coll, halt]


# ---------------------------------------------------------------------------
# Invariant monitors (Lemma 4 / Proposition 3), O(m^2) — for small-m tests.

def check_invariants(table, m: int, hash_seed: int):
    keys = E.dec_key(table)
    is_key = keys != jnp.uint32(E.RESERVED_KEY)
    is_final = is_key & (E.dec_tag(table) == E.TAG_FINAL)
    # Lemma 4: at most one <v, final> per key
    eq = keys[:, None] == keys[None, :]
    both_final = is_final[:, None] & is_final[None, :]
    off_diag = ~jnp.eye(m, dtype=bool)
    lemma4 = ~jnp.any(eq & both_final & off_diag)
    # Proposition 3: cells between h(v) and a cell containing v are non-empty
    hv = H.hash_keys(keys, m, hash_seed)
    idx = jnp.arange(m, dtype=jnp.int32)
    dist_cell = H.probe_distance(idx, hv, m)           # dist of cell c from h(key_c)
    # for cell c with key: no EMPTY cell e with dist(e, h(key_c)) < dist(c, h(key_c))
    dist_e = H.probe_distance(idx[None, :], hv[:, None], m)  # [c, e]
    empty = (table == jnp.uint32(E.EMPTY))[None, :]
    hole = empty & (dist_e < dist_cell[:, None])
    prop3 = ~jnp.any(is_key[:, None] & hole)
    return lemma4 & prop3


# ---------------------------------------------------------------------------
# Top-level simulation.

class Workload(NamedTuple):
    op: np.ndarray   # int32[P,K]  (OP_* or OP_NONE)
    key: np.ndarray  # uint32[P,K]


def _setup_op(r: PRegs, wl_op_row, wl_key_row, m: int, hash_seed: int) -> PRegs:
    """Prepare registers for the op at r.opidx (or HALT)."""
    K = wl_op_row.shape[0]
    in_range = r.opidx < K
    op = jnp.where(in_range, wl_op_row[jnp.clip(r.opidx, 0, K - 1)], OP_NONE)
    key = jnp.where(in_range, wl_key_row[jnp.clip(r.opidx, 0, K - 1)], 0)
    hv = H.hash_keys(jnp.uint32(key), m, hash_seed)
    active = op != OP_NONE
    started = _mk(r, op=op, v=key, hv=hv, i=hv, cont=CONT_FS, pc=FS_READ,
                  fresh=1, ll_cell=-1, ll_ver=0)
    halted = _mk(r, pc=HALT, op=OP_NONE)
    return _where_regs(active, started, halted)


def make_step(mode: str, m: int, hash_seed: int, wl_op, wl_key,
              check_inv: bool = False):
    """Build step(state, p) applying one scheduled event of process p."""
    posts = make_post(mode, m)
    wl_op = jnp.asarray(wl_op, dtype=jnp.int32)
    wl_key = jnp.asarray(wl_key, dtype=jnp.uint32)
    K = wl_op.shape[1]

    def step(state: SimState, p) -> SimState:
        R = state.regs
        r = PRegs(*(x[p] for x in R), complete=jnp.int32(0),
                  retval=jnp.int32(RET_PENDING))

        # record invocation time lazily
        fresh_now = (r.fresh == 1) & (r.pc != HALT)
        t_inv = state.t_inv.at[p, jnp.clip(r.opidx, 0, K - 1)].set(
            jnp.where(fresh_now, state.t, state.t_inv[p, jnp.clip(r.opidx, 0, K - 1)]))
        r = _mk(r, fresh=jnp.where(fresh_now, 0, r.fresh))

        kind, cell, oldv, oldo, newv, newo = memop_spec(r, mode)
        cell = jnp.clip(cell, 0, m - 1)
        (rval, ro, success, table, owner, ver, ll_cell, ll_ver, pair_ok,
         did_mem) = exec_memop(state.table, state.owner, state.ver, r, kind,
                               cell, oldv, oldo, newv, newo, mode)
        r = _mk(r, ll_cell=ll_cell, ll_ver=ll_ver)

        # step accounting
        opi = jnp.clip(r.opidx, 0, K - 1)
        steps = state.steps.at[p, opi].add(jnp.where(did_mem, 1, 0))

        r2 = jax.lax.switch(r.pc, posts, r, rval, ro, success)

        # completion handling
        comp = r2.complete == 1
        results = state.results.at[p, opi].set(
            jnp.where(comp, r2.retval, state.results[p, opi]))
        t_rsp = state.t_rsp.at[p, opi].set(
            jnp.where(comp, state.t, state.t_rsp[p, opi]))
        nxt = _setup_op(_mk(r2, opidx=r2.opidx + 1), wl_op[p], wl_key[p], m,
                        hash_seed)
        r3 = _where_regs(comp, nxt, r2)

        regs = Regs(*(x.at[p].set(getattr(r3, f))
                      for f, x in zip(Regs._fields, R)))
        new_pair = state.pair_ok & pair_ok
        inv_ok = state.inv_ok
        if check_inv:
            inv_ok = inv_ok & check_invariants(table, m, hash_seed)
        return SimState(table, owner, ver, regs, results, t_inv, t_rsp, steps,
                        state.t + 1, new_pair, inv_ok)

    return step


def init_state(mode: str, m: int, hash_seed: int, wl_op, wl_key) -> SimState:
    wl_op = jnp.asarray(wl_op, dtype=jnp.int32)
    wl_key = jnp.asarray(wl_key, dtype=jnp.uint32)
    P, K = wl_op.shape
    table = jnp.full((m,), E.EMPTY, dtype=jnp.uint32)
    owner = jnp.full((m,), E.NO_OWNER, dtype=jnp.int32)
    ver = jnp.zeros((m,), dtype=jnp.int32)
    zero = jnp.zeros((P,), dtype=jnp.int32)
    r = PRegs(pc=zero, opidx=zero, v=zero.astype(jnp.uint32), hv=zero, i=zero,
              j=zero, val=zero.astype(jnp.uint32), val_o=zero,
              cur=zero.astype(jnp.uint32), cur_o=zero, cont=zero,
              ll_cell=zero - 1, ll_ver=zero, fresh=zero, op=zero,
              complete=zero, retval=zero)
    # set up op 0 for every process
    rs = []
    for f in range(P):
        rp = PRegs(*(x[f] for x in r))
        rp = _setup_op(rp, wl_op[f], wl_key[f], m, hash_seed)
        rs.append(rp)
    regs = Regs(*(jnp.stack([getattr(rp, f) for rp in rs])
                  for f in Regs._fields[:15]))
    results = jnp.full((P, K), RET_PENDING, dtype=jnp.int32)
    t_inv = jnp.full((P, K), -1, dtype=jnp.int32)
    t_rsp = jnp.full((P, K), -1, dtype=jnp.int32)
    steps = jnp.zeros((P, K), dtype=jnp.int32)
    return SimState(table, owner, ver, regs, results, t_inv, t_rsp, steps,
                    jnp.int32(0), jnp.bool_(True), jnp.bool_(True))


@functools.partial(jax.jit, static_argnames=("mode", "m", "hash_seed",
                                             "check_inv"))
def _run_schedule(state: SimState, schedule, wl_op, wl_key, *, mode: str,
                  m: int, hash_seed: int, check_inv: bool) -> SimState:
    step = make_step(mode, m, hash_seed, wl_op, wl_key, check_inv)

    def body(st, p):
        return step(st, p), None

    state, _ = jax.lax.scan(body, state, schedule)
    return state


def simulate(wl: Workload, m: int, schedule, mode: str = MODE_LLSC,
             hash_seed: int = 0, check_inv: bool = False) -> SimState:
    """Run a full simulation: ``schedule`` is an int32[T] array of process ids
    (one shared-memory event each)."""
    wl_op = jnp.asarray(wl.op, dtype=jnp.int32)
    wl_key = jnp.asarray(wl.key, dtype=jnp.uint32)
    state = init_state(mode, m, hash_seed, wl_op, wl_key)
    schedule = jnp.asarray(schedule, dtype=jnp.int32)
    return _run_schedule(state, schedule, wl_op, wl_key, mode=mode, m=m,
                         hash_seed=hash_seed, check_inv=check_inv)


def history_arrays(state: SimState, wl: Workload):
    """Extract (proc, opidx, op, key, ret, t_inv, t_rsp) numpy arrays of all
    invoked operations, for the linearizability checker."""
    op = np.asarray(wl.op)
    key = np.asarray(wl.key)
    res = np.asarray(state.results)
    t_inv = np.asarray(state.t_inv)
    t_rsp = np.asarray(state.t_rsp)
    P, K = op.shape
    rows = []
    for p in range(P):
        for k in range(K):
            if op[p, k] == OP_NONE or t_inv[p, k] < 0:
                continue
            rows.append((p, k, int(op[p, k]), int(key[p, k]), int(res[p, k]),
                         int(t_inv[p, k]), int(t_rsp[p, k])))
    return rows
