"""Mesh-sharded distributed hash table (DHT).

The table is hash-partitioned across one mesh axis (usually ``model``):
shard-of-key is a hash of the key, independent of the within-shard probe
hash.  Operations are routed to the owning shard with the MoE-dispatch
pattern — capacity-bounded bucketing + ``jax.lax.all_to_all`` — applied
locally with the batched engine (scatter-min arbitration, tombstone reuse),
and results are routed back.  This is the paper's "shared memory accessed by
n processes" reshaped for a TPU mesh: chips are the processes, the ICI
all-to-all is the interconnect, and per-shard batch application provides the
same linearizable per-key semantics because every key has a single owner
shard (single-owner ⇒ per-key operations serialize at the owner — the
distributed analog of the paper's per-cell atomicity).

All functions here are designed to be called INSIDE ``shard_map`` (they use
``axis_name`` collectives); ``make_sharded_table`` builds the jitted
outer functions for a given mesh.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import batched as BT
from repro.core import encoding as E
from repro.core import hashing as H
from repro.core.spec import OP_LOOKUP
from repro.dist.compat import axis_size, shard_map

SHARD_SEED = 0x5EED


class ShardedTable(NamedTuple):
    """Global view: leaves sharded over the table axis."""
    table: jnp.ndarray      # uint32[S, m_local]
    num_keys: jnp.ndarray   # int32[S]
    num_tombs: jnp.ndarray  # int32[S]
    seed: jnp.ndarray       # int32[S]


def create_sharded(num_shards: int, m_local: int, seed: int = 0) -> ShardedTable:
    return ShardedTable(
        table=jnp.full((num_shards, m_local), E.EMPTY, dtype=jnp.uint32),
        num_keys=jnp.zeros((num_shards,), jnp.int32),
        num_tombs=jnp.zeros((num_shards,), jnp.int32),
        seed=jnp.full((num_shards,), seed, jnp.int32),
    )


def shard_of(keys, num_shards: int):
    """Owner shard of each key (independent hash from the probe hash)."""
    return H.hash_keys(jnp.asarray(keys, jnp.uint32), num_shards, SHARD_SEED)


def _local_view(st: ShardedTable) -> BT.HashTable:
    """Per-device view inside shard_map: leading shard dim of size 1."""
    return BT.HashTable(table=st.table[0], num_keys=st.num_keys[0],
                        num_tombs=st.num_tombs[0], seed=st.seed[0],
                        meta=jnp.zeros((0,), jnp.uint32))


def _pack_local(ht: BT.HashTable) -> ShardedTable:
    return ShardedTable(table=ht.table[None], num_keys=ht.num_keys[None],
                        num_tombs=ht.num_tombs[None], seed=ht.seed[None])


def routed_apply(st_local: ShardedTable, ops, keys, *, axis_name: str,
                 capacity: int):
    """INSIDE shard_map: apply (ops, keys) of this device's local request
    batch to the distributed table.

    Returns (st_local', ret int32[B], overflowed bool[B]).  Overflowed
    requests (more than ``capacity`` requests from this device to one shard)
    are not applied and return -1; callers retry them in the next batch
    (production note: capacity is sized so overflow is statistically rare,
    like MoE expert capacity).
    """
    ops = jnp.asarray(ops, jnp.int32)
    keys = jnp.asarray(keys, jnp.uint32)
    B = keys.shape[0]
    S = axis_size(axis_name)

    dest = shard_of(keys, S)                              # [B]
    # position of each request within its destination bucket
    onehot = jax.nn.one_hot(dest, S, dtype=jnp.int32)     # [B, S]
    pos_in_bucket = (jnp.cumsum(onehot, axis=0) - 1)      # [B, S]
    pos = jnp.take_along_axis(pos_in_bucket, dest[:, None], axis=1)[:, 0]
    ok = pos < capacity
    flat = dest * capacity + pos                          # [B]
    flat = jnp.where(ok, flat, S * capacity)              # OOB -> drop

    send_keys = jnp.full((S * capacity,), E.MAX_KEY, jnp.uint32)
    send_keys = send_keys.at[flat].set(keys, mode="drop")
    send_ops = jnp.full((S * capacity,), OP_LOOKUP, jnp.int32)
    send_ops = send_ops.at[flat].set(ops, mode="drop")
    send_act = jnp.zeros((S * capacity,), bool).at[flat].set(ok, mode="drop")

    # exchange: chunk s of my buffer goes to shard s (tiled all_to_all over
    # the flat [S*capacity] layout — the MoE dispatch idiom)
    a2a = functools.partial(jax.lax.all_to_all, axis_name=axis_name,
                            split_axis=0, concat_axis=0, tiled=True)
    rk = a2a(send_keys)
    rop = a2a(send_ops)
    ract = a2a(send_act.astype(jnp.int32)) > 0

    ht = _local_view(st_local)
    from repro.core.spec import OP_DELETE, OP_INSERT
    ht, del_ret = BT.delete_batch(ht, rk, active=ract & (rop == OP_DELETE))
    ht, ins_ret = BT.insert_batch(ht, rk, active=ract & (rop == OP_INSERT))
    look_ret = BT.lookup_batch(ht, rk).astype(jnp.int32)
    rret = jnp.where(rop == OP_DELETE, del_ret,
                     jnp.where(rop == OP_INSERT, ins_ret, look_ret))
    rret = jnp.where(ract, rret, -1)

    # route results back
    back = a2a(rret)
    safe_flat = jnp.where(ok, flat, 0)
    ret = jnp.where(ok, back[safe_flat], -1)
    return _pack_local(ht), ret, ~ok


def make_sharded_table(mesh: Mesh, axis: str, m_global: int,
                       capacity: int, seed: int = 0):
    """Build (state, apply_fn) for a DHT sharded over ``mesh[axis]``.

    ``apply_fn(state, ops, keys)``: ops/keys are [S*B_local] arrays sharded
    over ``axis``; returns (state', ret, overflow).
    """
    S = mesh.shape[axis]
    assert m_global % S == 0
    m_local = m_global // S
    st = create_sharded(S, m_local, seed)

    table_spec = ShardedTable(P(axis, None), P(axis), P(axis), P(axis))
    st = jax.device_put(st, jax.tree.map(
        lambda s: NamedSharding(mesh, s), table_spec,
        is_leaf=lambda x: isinstance(x, P)))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(table_spec, P(axis), P(axis)),
        out_specs=(table_spec, P(axis), P(axis)),
        check_vma=False)
    def _apply(st_local, ops, keys):
        st2, ret, ovf = routed_apply(st_local, ops, keys, axis_name=axis,
                                     capacity=capacity)
        return st2, ret, ovf

    def apply_fn(state, ops, keys):
        return jax.jit(_apply)(state, ops, keys)

    return st, apply_fn
