"""TPU-native batched linear-probing hash table.

This is the production-path adaptation of the paper's algorithm (DESIGN.md
§2).  On TPU the ``n`` asynchronous processes of the paper become the ``B``
lanes of a batch; per-word CAS becomes **scatter-min priority arbitration**
(optimistic claim / check-who-won / retry — the same optimistic-concurrency
structure, data-parallel and deterministic); and the paper's probe-order
priority rule resolves duplicate keys inside a batch exactly as it resolves
concurrent same-key inserts.  Tombstone reuse — the paper's space-efficiency
headline — carries over unchanged: inserts claim EMPTY *or* TOMBSTONE cells,
so the table never needs rebuilding while #keys <= m (Proposition 2 analog).

Between batch applications the table is *quiescent*: cells hold only
``<v, final>`` / EMPTY / TOMBSTONE.  The tentative/validate life cycle of the
paper materializes inside ``insert_batch``'s arbitration rounds (claims that
lose a round are withdrawn — the batched analog of COLLIDED/withdraw).
The resulting table state equals a sequential execution of SOME serialization
of the batch (the paper's Proposition 20: the specific effective insertion
schedule is irrelevant to the run-length distribution), and the *returns*
match the by-batch-index serialization exactly.

Semantics: ``apply_batch`` linearizes a mixed batch as
    all deletes (by batch index) < all inserts (by batch index) < all lookups
which is one valid serialization.

Wait-free lookups are pure vectorized reads (no lane ever retries because of
another lane's writes) — ``kernels/probe`` provides the Pallas VMEM-tiled
version; this module is its jnp oracle and the general-purpose path.

Probe strategies: every operation takes a static ``strategy`` keyword
(default ``"linear"``).  The linear implementation lives inline below,
bitwise-identical to its pre-ProbeStrategy form (pinned by the recorded-
trace parity test); ``"robinhood"`` and ``"hopscotch"`` dispatch to
``core/probe_strategies.py``.  ``HashTable.meta`` carries per-entry strategy
metadata as one extra uint32 pytree leaf (hopscotch neighborhood bitmaps;
empty for linear/robinhood).

Keys must lie in ``[0, encoding.MAX_KEY)``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import encoding as E
from repro.core import hashing as H

PROBE_CHUNK = 8  # cells fetched per probe round in the jnp path


class HashTable(NamedTuple):
    """Quiescent table state (a pytree; all ops are functional)."""
    table: jnp.ndarray      # uint32[m]: enc_final(key) / EMPTY / TOMBSTONE
    num_keys: jnp.ndarray   # int32: live keys
    num_tombs: jnp.ndarray  # int32: tombstones
    seed: jnp.ndarray       # int32: hash seed
    meta: jnp.ndarray       # uint32[m] strategy metadata (uint32[0] if none)


def _strategy_impl(strategy: str):
    from repro.core import probe_strategies as PS  # lazy: avoids cycle
    return PS.get_strategy(strategy)


def create(m: int, seed: int = 0, strategy: str = "linear") -> HashTable:
    meta = (jnp.zeros((0,), jnp.uint32) if strategy == "linear"
            else _strategy_impl(strategy).init_meta(m))
    return HashTable(
        table=jnp.full((m,), E.EMPTY, dtype=jnp.uint32),
        num_keys=jnp.int32(0),
        num_tombs=jnp.int32(0),
        seed=jnp.int32(seed),
        meta=meta,
    )


def size(ht: HashTable) -> int:
    return ht.table.shape[0]


def _hash(ht: HashTable, keys):
    # fold the (traced) seed into the key stream
    mix = ht.seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    return H.hash_keys(jnp.asarray(keys, jnp.uint32) ^ mix, size(ht), 0)


def _active_mask(B, active):
    if active is None:
        return jnp.ones((B,), bool)
    return jnp.asarray(active, bool)


# ---------------------------------------------------------------------------
# Lookup — wait-free, read-only.

def find_batch(ht: HashTable, keys, active=None, *,
               strategy: str = "linear") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (found bool[B], slot int32[B]) — slot of <key, final>, or -1.

    Linear/robinhood scan each key's run in PROBE_CHUNK-cell windows until
    the key or an EMPTY cell (end of run) is found; hopscotch gathers the
    bitmap-indicated neighborhood instead (bounded).
    """
    if strategy not in ("linear", "robinhood"):
        return _strategy_impl(strategy).find_batch(ht, keys, active)
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    m = size(ht)
    B = keys.shape[0]
    act = _active_mask(B, active)
    hv = _hash(ht, keys)
    target = (keys << 2) | E.TAG_FINAL

    max_rounds = (m + PROBE_CHUNK - 1) // PROBE_CHUNK
    woff = jnp.arange(PROBE_CHUNK, dtype=jnp.int32)

    def cond(st):
        step, scanning, found, slot = st
        return jnp.any(scanning) & (step < max_rounds)

    def body(st):
        step, scanning, found, slot = st
        pos = jnp.mod(hv[:, None] + step * PROBE_CHUNK + woff[None, :], m)
        vals = ht.table[pos]                            # [B, W]
        hit = vals == target[:, None]
        empty = vals == jnp.uint32(E.EMPTY)
        hit_any = jnp.any(hit, axis=1)
        empty_any = jnp.any(empty, axis=1)
        hit_first = jnp.argmax(hit, axis=1)
        empty_first = jnp.argmax(empty, axis=1)
        hit_valid = hit_any & (~empty_any | (hit_first <= empty_first))
        new_found = found | (scanning & hit_valid)
        new_slot = jnp.where(scanning & hit_valid,
                             jnp.take_along_axis(pos, hit_first[:, None],
                                                 axis=1)[:, 0], slot)
        new_scanning = scanning & ~hit_valid & ~empty_any
        return step + 1, new_scanning, new_found, new_slot

    st0 = (jnp.int32(0), act, jnp.zeros((B,), bool),
           jnp.full((B,), -1, jnp.int32))
    _, _, found, slot = jax.lax.while_loop(cond, body, st0)
    return found, slot


def lookup_batch(ht: HashTable, keys, active=None, *,
                 strategy: str = "linear") -> jnp.ndarray:
    """Wait-free batched lookup: present?"""
    found, _ = find_batch(ht, keys, active, strategy=strategy)
    return found


# ---------------------------------------------------------------------------
# Insert — scatter-min arbitration rounds (the batched CAS analog).

def _dedup_leaders(keys, act) -> jnp.ndarray:
    """leader[b] = is b the first *active* occurrence of keys[b]?"""
    B = keys.shape[0]
    eq = keys[None, :] == keys[:, None]               # [i, j]
    earlier = jnp.tril(jnp.ones((B, B), bool), k=-1)  # j < i
    dup_of_earlier = jnp.any(eq & earlier & act[None, :], axis=1)
    return ~dup_of_earlier & act


def insert_batch(ht: HashTable, keys, active=None,
                 claim_tombstones: bool = True, *,
                 strategy: str = "linear") -> Tuple[HashTable, jnp.ndarray]:
    """Insert a batch; ret int32[B]: 1=true (inserted), 0=false (present or
    duplicate-in-batch or inactive), 2=ABORT (no available cell).

    ``claim_tombstones=False`` reproduces the no-reuse behaviour of [7,14]
    (Gao et al. / Maier et al.): tombstones accumulate and only EMPTY cells
    are claimable — the baseline the paper improves on (see
    core/baselines/gao_noreuse.py and the ``bench_reuse`` benchmark)."""
    if strategy != "linear":
        return _strategy_impl(strategy).insert_batch(ht, keys, active,
                                                     claim_tombstones)
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    m = size(ht)
    B = keys.shape[0]
    act = _active_mask(B, active)
    hv = _hash(ht, keys)
    leader = _dedup_leaders(keys, act)
    present, _ = find_batch(ht, keys, act)

    pri = jnp.arange(B, dtype=jnp.int32)

    def cond(st):
        table, cursor, pending, placed, aborted, tombs_used = st
        return jnp.any(pending)

    def body(st):
        table, cursor, pending, placed, aborted, tombs_used = st
        cand = jnp.mod(hv + cursor, m)
        if claim_tombstones:
            avail = E.is_available(table[cand]) & pending
        else:
            avail = (table[cand] == jnp.uint32(E.EMPTY)) & pending
        # claim: lowest batch index wins each contested cell
        claim_idx = jnp.where(avail, cand, m)  # OOB -> dropped
        claims = jnp.full((m,), B, jnp.int32).at[claim_idx].min(
            pri, mode="drop")
        won = avail & (claims[cand] == pri)
        was_tomb = won & (table[cand] == jnp.uint32(E.TOMBSTONE))
        write_idx = jnp.where(won, cand, m)
        table = table.at[write_idx].set((keys << 2) | E.TAG_FINAL,
                                        mode="drop")
        tombs_used = tombs_used + jnp.sum(was_tomb)
        placed = placed | won
        # losers / occupied cells: advance cursor; full cycle -> ABORT
        adv = pending & ~won
        cursor = jnp.where(adv, cursor + 1, cursor)
        ab = adv & (cursor >= m)
        aborted = aborted | ab
        pending = pending & ~won & ~ab
        return table, cursor, pending, placed, aborted, tombs_used

    st0 = (ht.table, jnp.zeros((B,), jnp.int32), leader & ~present,
           jnp.zeros((B,), bool), jnp.zeros((B,), bool), jnp.int32(0))
    table, _, _, placed, aborted, tombs_used = jax.lax.while_loop(
        cond, body, st0)

    ret = jnp.zeros((B,), jnp.int32)
    ret = jnp.where(placed, 1, ret)
    ret = jnp.where(aborted, 2, ret)
    # a non-leader duplicate of an aborted leader also aborts (sequentially
    # the leader ran first and the table is still full, key still absent)
    eq = keys[None, :] == keys[:, None]
    earlier = jnp.tril(jnp.ones((B, B), bool), k=-1)
    leader_aborted = jnp.any(eq & earlier & aborted[None, :], axis=1)
    ret = jnp.where(act & ~leader & ~present & leader_aborted, 2, ret)

    ht2 = ht._replace(table=table,
                      num_keys=ht.num_keys + jnp.sum(placed),
                      num_tombs=ht.num_tombs - tombs_used)
    return ht2, ret


# ---------------------------------------------------------------------------
# Delete — find + tombstone.

def delete_batch(ht: HashTable, keys, active=None, *,
                 strategy: str = "linear") -> Tuple[HashTable, jnp.ndarray]:
    if strategy not in ("linear", "robinhood"):
        return _strategy_impl(strategy).delete_batch(ht, keys, active)
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    m = size(ht)
    B = keys.shape[0]
    act = _active_mask(B, active)
    found, slot = find_batch(ht, keys, act)
    leader = _dedup_leaders(keys, act)
    win = found & leader
    idx = jnp.where(win, slot, m)
    table = ht.table.at[idx].set(jnp.uint32(E.TOMBSTONE), mode="drop")
    ret = win.astype(jnp.int32)
    ht2 = ht._replace(table=table,
                      num_keys=ht.num_keys - jnp.sum(win),
                      num_tombs=ht.num_tombs + jnp.sum(win))
    return ht2, ret


# ---------------------------------------------------------------------------
# Mixed batch + maintenance.

def apply_batch(ht: HashTable, ops, keys, *, strategy: str = "linear"):
    """ops int32[B] (spec.OP_*), keys uint32[B].  Linearization order:
    deletes < inserts < lookups (each group by batch index).
    Returns (ht', ret int32[B])."""
    from repro.core.spec import OP_DELETE, OP_INSERT
    ops = jnp.asarray(ops, jnp.int32)
    keys = jnp.asarray(keys, jnp.uint32)
    ht, del_ret = delete_batch(ht, keys, active=(ops == OP_DELETE),
                               strategy=strategy)
    ht, ins_ret = insert_batch(ht, keys, active=(ops == OP_INSERT),
                               strategy=strategy)
    look_ret = lookup_batch(ht, keys, strategy=strategy).astype(jnp.int32)
    ret = jnp.where(ops == OP_DELETE, del_ret,
                    jnp.where(ops == OP_INSERT, ins_ret, look_ret))
    return ht, ret


def load_factor(ht: HashTable):
    return ht.num_keys / size(ht)


def occupancy(ht: HashTable):
    """Fraction of non-EMPTY cells (keys + tombstones) — what forces rebuilds
    in no-reuse designs."""
    return (ht.num_keys + ht.num_tombs) / size(ht)


def live_keys(ht: HashTable) -> jnp.ndarray:
    """uint32[m] array: live keys packed first, padded with MAX_KEY."""
    is_key = E.dec_key(ht.table) != jnp.uint32(E.RESERVED_KEY)
    keys = jnp.where(is_key, E.dec_key(ht.table), jnp.uint32(E.MAX_KEY))
    order = jnp.argsort(~is_key, stable=True)
    return keys[order], jnp.sum(is_key)


def rebuild(ht: HashTable, new_m: int,
            new_seed: Optional[int] = None, *,
            strategy: str = "linear") -> HashTable:
    """Resize/rebuild (Section 4.3: triggered by ABORTs; standard technique,
    orthogonal to the lock-free algorithm itself)."""
    keys_sorted, n_live = live_keys(ht)
    fresh = create(new_m, int(ht.seed) if new_seed is None else new_seed,
                   strategy=strategy)
    m = size(ht)
    fresh, _ = insert_batch(fresh, keys_sorted,
                            active=(jnp.arange(m) < n_live),
                            strategy=strategy)
    return fresh
