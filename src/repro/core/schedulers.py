"""Workload and schedule generators for the concurrent simulator.

The paper's model is an adversarial asynchronous scheduler.  We provide:

* ``uniform_schedule`` — i.i.d. uniform process choice per event (the standard
  stochastic adversary).
* ``bursty_schedule`` — processes run in random-length bursts (more
  sequential-ish interleavings; stresses different races).
* ``stalled_schedule`` — one victim process is starved for a long window and
  then released (exercises the "revalidate / resurrect" machinery: other
  processes observe its tentative copy mid-flight).
* ``round_robin_schedule``.
* ``make_cbounded_workload`` — the paper's *c-bounded fixed-workload*
  scheduler setup (Section 5.4): a fixed batch of operations, at most c
  concurrent ops per key, at most one concurrent insert per key.  Keys are
  partitioned among process groups of size <= c, and at most one process per
  group issues inserts, so the bound holds under ANY schedule.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import Workload
from repro.core.spec import OP_DELETE, OP_INSERT, OP_LOOKUP, OP_NONE


def uniform_schedule(rng: np.random.Generator, P: int, T: int) -> np.ndarray:
    return rng.integers(0, P, size=T).astype(np.int32)


def round_robin_schedule(P: int, T: int) -> np.ndarray:
    return (np.arange(T) % P).astype(np.int32)


def bursty_schedule(rng: np.random.Generator, P: int, T: int,
                    mean_burst: int = 8) -> np.ndarray:
    out = np.empty(T, dtype=np.int32)
    t = 0
    while t < T:
        p = rng.integers(0, P)
        b = 1 + rng.geometric(1.0 / mean_burst)
        out[t:t + b] = p
        t += b
    return out[:T]


def stalled_schedule(rng: np.random.Generator, P: int, T: int,
                     victim: int = 0, stall_frac: float = 0.6) -> np.ndarray:
    """Victim takes a few steps, is starved for ``stall_frac`` of the run,
    then released to finish."""
    sched = rng.integers(0, P, size=T).astype(np.int32)
    start = int(T * 0.05)
    stop = int(T * (0.05 + stall_frac))
    window = sched[start:stop]
    window[window == victim] = (victim + 1) % P
    sched[start:stop] = window
    return sched


def random_workload(rng: np.random.Generator, P: int, K: int, num_keys: int,
                    p_insert: float = 0.4, p_delete: float = 0.3,
                    keys: np.ndarray | None = None) -> Workload:
    """Uniformly random ops over a small key universe — maximal contention."""
    if keys is None:
        keys = rng.integers(0, num_keys, size=(P, K)).astype(np.uint32)
    r = rng.random((P, K))
    op = np.full((P, K), OP_LOOKUP, dtype=np.int32)
    op[r < p_insert] = OP_INSERT
    op[(r >= p_insert) & (r < p_insert + p_delete)] = OP_DELETE
    return Workload(op=op, key=keys.astype(np.uint32))


def same_key_workload(P: int, K: int, key: int = 7,
                      pattern: str = "insert_delete") -> Workload:
    """All processes hammer a single key — the worst case for the duplicate-
    elimination machinery (Figure 2 scenarios)."""
    op = np.zeros((P, K), dtype=np.int32)
    if pattern == "insert_delete":
        op[:, 0::3] = OP_INSERT
        op[:, 1::3] = OP_DELETE
        op[:, 2::3] = OP_LOOKUP
    elif pattern == "insert_only":
        op[:] = OP_INSERT
    elif pattern == "mixed":
        op[0::2, 0::2] = OP_INSERT
        op[0::2, 1::2] = OP_DELETE
        op[1::2, :] = OP_LOOKUP
    key_arr = np.full((P, K), key, dtype=np.uint32)
    return Workload(op=op, key=key_arr)


def make_cbounded_workload(rng: np.random.Generator, P: int, K: int,
                           c: int, num_keys: int,
                           insert_frac: float = 0.5) -> Workload:
    """Section 5.4 setup: processes are partitioned into groups of size <= c;
    each group owns a disjoint key set; only the group's first process issues
    inserts (and deletes of its own keys), others only lookup/delete.  Under
    ANY schedule: point contention per key <= c and at most one concurrent
    insert per key."""
    n_groups = max(1, P // max(1, c))
    group_of = np.arange(P) % n_groups
    keys_per_group = max(1, num_keys // n_groups)
    op = np.full((P, K), OP_NONE, dtype=np.int32)
    key = np.zeros((P, K), dtype=np.uint32)
    for p in range(P):
        g = group_of[p]
        base = g * keys_per_group
        ks = base + rng.integers(0, keys_per_group, size=K)
        key[p] = ks.astype(np.uint32)
        is_leader = (p == int(np.argmax(group_of == g)))
        if is_leader:
            r = rng.random(K)
            op[p] = np.where(r < insert_frac, OP_INSERT,
                             np.where(r < insert_frac + 0.25, OP_DELETE,
                                      OP_LOOKUP))
        else:
            r = rng.random(K)
            op[p] = np.where(r < 0.5, OP_LOOKUP, OP_DELETE)
    return Workload(op=op, key=key)


def insert_only_distinct(P: int, K: int, start: int = 0) -> Workload:
    """P*K distinct keys, insert-only — for Knuth-style load-factor sweeps
    (no concurrent same-key inserts, Proposition 20 applies)."""
    op = np.full((P, K), OP_INSERT, dtype=np.int32)
    key = (start + np.arange(P * K).reshape(P, K)).astype(np.uint32)
    return Workload(op=op, key=key)
