"""Linearizability checker for dictionary histories.

Per the locality theorem (used by the paper in Section 5.2), a history is
linearizable iff each per-key projection is linearizable, so we check each
key independently against the single-key dictionary automaton
(``spec.legal_next``): state = "key present?".

Within a key we additionally decompose the history at *quiescent points*
(moments where no operation on that key is pending); the chunks between
quiescent points must linearize in order, carrying forward the set of
reachable presence-states.  Inside a chunk we run a memoized DFS over
(linearized-set bitmask, presence) states — exact, exponential only in the
maximum overlap degree, which is small for our workloads.

Pending operations (invoked, no response) MAY be linearized (with any legal
return) or omitted, per the definition of a completion of a history.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from repro.core.spec import (OP_DELETE, OP_INSERT, OP_LOOKUP, RET_ABORT,
                             RET_FALSE, RET_PENDING, RET_TRUE, legal_next)

INF = 1 << 60


@dataclass(frozen=True)
class HEvent:
    """One operation instance in a history."""
    op: int
    key: int
    ret: int          # RET_* (RET_PENDING if no response)
    t_inv: int
    t_rsp: int        # -1 if pending

    @property
    def pending(self) -> bool:
        return self.t_rsp < 0 or self.ret == RET_PENDING

    @property
    def rsp(self) -> int:
        return INF if self.pending else self.t_rsp


def _legal_appends(present: bool, op: int, ret: int) -> List[bool]:
    """Next-presence options when appending (op, ret); [] if illegal.
    For pending ops (ret == RET_PENDING) any legal return is allowed."""
    if ret != RET_PENDING:
        ok, nxt = legal_next(present, op, ret)
        return [nxt] if ok else []
    outs = []
    for r in (RET_FALSE, RET_TRUE, RET_ABORT):
        if op != OP_INSERT and r == RET_ABORT:
            continue
        ok, nxt = legal_next(present, op, r)
        if ok and nxt not in outs:
            outs.append(nxt)
    return outs


def _check_chunk(evs: List[HEvent], init_states: Set[bool]) -> Set[bool]:
    """Exact search: which presence-states are reachable after linearizing
    all completed ops of ``evs`` (pending ops optional)?  Empty set == not
    linearizable."""
    n = len(evs)
    if n == 0:
        return set(init_states)
    full_completed = 0
    for idx, e in enumerate(evs):
        if not e.pending:
            full_completed |= (1 << idx)

    # precedence: e must come after all completed ops whose rsp < e.inv
    preds = []
    for e in evs:
        p = 0
        for jdx, f in enumerate(evs):
            if not f.pending and f.t_rsp < e.t_inv:
                p |= (1 << jdx)
        preds.append(p)

    finals: Set[bool] = set()
    seen: Set[Tuple[int, bool]] = set()
    stack: List[Tuple[int, bool]] = [(0, s) for s in init_states]
    while stack:
        mask, present = stack.pop()
        if (mask, present) in seen:
            continue
        seen.add((mask, present))
        if (mask & full_completed) == full_completed:
            finals.add(present)
            # keep exploring: pending ops may still be linearized, possibly
            # changing the carried state
        for idx, e in enumerate(evs):
            bit = 1 << idx
            if mask & bit:
                continue
            if (preds[idx] & ~mask):
                continue  # a predecessor not yet linearized
            for nxt in _legal_appends(present, e.op, e.ret):
                stack.append((mask | bit, nxt))
    return finals


def check_key_history(evs: Sequence[HEvent],
                      initial_present: bool = False) -> bool:
    """Is the per-key history linearizable?"""
    evs = sorted(evs, key=lambda e: (e.t_inv, e.rsp))
    # split at quiescent points
    chunks: List[List[HEvent]] = []
    cur: List[HEvent] = []
    cur_max_rsp = -1
    for e in evs:
        if cur and e.t_inv > cur_max_rsp:
            chunks.append(cur)
            cur = []
            cur_max_rsp = -1
        cur.append(e)
        cur_max_rsp = max(cur_max_rsp, e.rsp)
    if cur:
        chunks.append(cur)

    states: Set[bool] = {initial_present}
    for ch in chunks:
        states = _check_chunk(ch, states)
        if not states:
            return False
    return True


def check_history(rows: Iterable[Tuple[int, int, int, int, int, int, int]],
                  initial_present: Dict[int, bool] | None = None) -> Tuple[bool, List[int]]:
    """Check a whole history.

    ``rows``: iterable of (proc, opidx, op, key, ret, t_inv, t_rsp) as
    produced by ``simulator.history_arrays``.  Returns (ok, bad_keys).
    """
    initial_present = initial_present or {}
    by_key: Dict[int, List[HEvent]] = {}
    for (_p, _k, op, key, ret, t_inv, t_rsp) in rows:
        pend = t_rsp < 0
        by_key.setdefault(key, []).append(
            HEvent(op=op, key=key, ret=(RET_PENDING if pend else ret),
                   t_inv=t_inv, t_rsp=(-1 if pend else t_rsp)))
    bad = []
    for key, evs in by_key.items():
        if not check_key_history(evs, initial_present.get(key, False)):
            bad.append(key)
    return (len(bad) == 0), bad
