"""Request-span tracing on the virtual clock (tentpole b).

A trace is a JSONL stream of events, one object per line, emitted by the
scheduler (request lifecycle), the ``ContinuousBatcher`` / sharded
simulator (per-round decode + table health), and the ``PrefixRouter``
(grow / lose-host / migration interleaving).  Every event carries the
VIRTUAL clock (decode steps) — never wall time — and is serialized with
``sort_keys`` + fixed separators, so a run is **byte-identical** across
machines and repetitions (pinned by ``tests/test_obs.py``).

Span schema (event -> required fields beyond ``clock``/``event``):

    arrival       req                      request entered the queue
    admit         req, slot, prefill, readmit   (readmit = prior preemptions)
    first_token   req                      first decode token surfaced
    preempt       req, slot                proactive eviction back to QUEUED
    finish        req, tokens, ttft, tpot  terminal; idempotent upstream
    abort         lanes, grew_to           reactive allocator ABORT latch
    decode        reqs, tokens, pages      one megastep round (per shard)
    round         counters{...}, health{...}    driver round roll-up
    shard_health  live, tombs, n_cells, free, tomb_density, probe_p99,
                  migrated, migration_left       per-shard, per-round gauge
    grow          n_pages_old, n_pages_new       lazy resize began (window
                                                 OPENS: old table frozen)
    migrate       moved                    one service round's sweep (may
                                           move 0 — emitted each round the
                                           window is open)
    migrate_done  —                        window CLOSES (old table retired)
    lose_host     victims                  host-group loss + re-homing
    summary       sched stats roll-up      exactly once, last line

Shard-scoped events additionally carry ``shard``.  ``tools/trace_report.py``
renders timelines/health curves from this stream and checks the trace
invariants listed in ``obs/README.md``.
"""
from __future__ import annotations

import json
import os
from typing import IO, Optional, Union

import numpy as np


def _plain(v):
    """Coerce numpy scalars/arrays so the JSON encoder stays deterministic
    (no platform-dependent reprs)."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return [_plain(x) for x in v.tolist()]
    if isinstance(v, dict):
        return {str(k): _plain(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_plain(x) for x in v]
    return v


class Tracer:
    """Append-only deterministic JSONL writer.

    ``sink`` is a path or an open text file.  Emission order is program
    order; within one clock value the order is still deterministic because
    every emitter runs on the single-threaded driver.
    """

    def __init__(self, sink: Union[str, IO[str]]):
        if hasattr(sink, "write"):
            self._f: IO[str] = sink  # type: ignore[assignment]
            self._owns = False
            self.path: Optional[str] = getattr(sink, "name", None)
        else:
            d = os.path.dirname(str(sink))
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(sink, "w")
            self._owns = True
            self.path = str(sink)
        self.n_events = 0

    def emit(self, event: str, clock: int, **fields) -> None:
        rec = {"event": str(event), "clock": int(clock)}
        rec.update(_plain(fields))
        self._f.write(json.dumps(rec, sort_keys=True,
                                 separators=(",", ":")) + "\n")
        self.n_events += 1

    def close(self) -> None:
        self._f.flush()
        if self._owns:
            self._f.close()

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path: str):
    """Parse a JSONL trace back into a list of event dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
