"""Unified telemetry: on-device counter plane, span tracing, metrics
registry.  See ``obs/README.md`` for the design and the trace invariants
``tools/trace_report.py`` enforces."""
from repro.obs.counters import (Counters, HOST_COUNTERS, delta,
                                host_counters_scope, note_free, note_host,
                                snapshot, update_token_counters)
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer, read_trace

__all__ = [
    "Counters", "HOST_COUNTERS", "delta", "host_counters_scope",
    "note_free", "note_host", "snapshot", "update_token_counters",
    "MetricsRegistry", "Tracer", "read_trace",
]
