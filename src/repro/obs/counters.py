"""On-device counter plane (tentpole a of the unified telemetry layer).

``Counters`` is a tiny pytree of scalar int32 leaves that rides INSIDE the
decode state dict (``state["counters"]``), so it flows through the megastep
``lax.scan`` like any other recurrent leaf and crosses the device boundary
exactly when the batcher already fetches ``state["pos"]`` — the once-per-K
host sync.  Telemetry therefore adds **zero extra device syncs**: the
counters are accumulated in-graph (a handful of scalar adds per token) and
read out for free in the post-dispatch host section.

The plane is guarded by ``cfg.telemetry`` with an identity fast path: when
the knob is off, ``make_decode_state`` never creates the leaf and every
update site keys on ``"counters" in state`` — the traced program is
*bitwise identical* to the un-instrumented one (pinned by
``tests/test_obs.py::test_telemetry_off_parity``).

Two planes share this schema:

* **device plane** — jnp scalars inside the engine state, updated by
  ``serving/engine`` (token body / serve step) and eagerly by the batcher
  between rounds (frees, rebuild events); and
* **host plane** — plain-int module counters (``HOST_COUNTERS``) for the
  eager paths that never enter a trace: ``dist/table_shard`` migration
  sweeps and the sharded simulator.  Same field names, so
  ``snapshot``/``delta`` work on either.
"""
from __future__ import annotations

import contextlib
from typing import Dict, NamedTuple

import jax.numpy as jnp


class Counters(NamedTuple):
    """Monotone event counts since state creation (scalar int32 each).

    ``snapshot`` them cumulatively and difference on the host; per-round
    rates are then exact even though the device only ever accumulates.
    """

    probe_steps: jnp.ndarray          # hash-table probe steps (alloc path)
    pages_allocated: jnp.ndarray      # page-boundary inserts that landed
    pages_freed: jnp.ndarray          # pages deleted on sequence free
    tombstones_created: jnp.ndarray   # deletes that left a TOMBSTONE
    tombstones_reclaimed: jnp.ndarray  # inserts that re-claimed a TOMBSTONE
    abort_events: jnp.ndarray         # lanes newly latched ABORT
    tokens_accepted: jnp.ndarray      # decode tokens committed (act lanes)
    migration_moved: jnp.ndarray      # entries moved by lazy-resize sweeps

    @classmethod
    def zeros(cls) -> "Counters":
        z = jnp.zeros((), jnp.int32)
        return cls(*([z] * len(cls._fields)))

    @classmethod
    def axes(cls) -> "Counters":
        """Per-leaf sharding axes, all replicated scalars — the
        ``make_decode_state`` axes-dict entry (HashTable ``num_keys``
        pattern)."""
        return cls(*([()] * len(cls._fields)))


def snapshot(c) -> Dict[str, int]:
    """Materialize a Counters (device or host plane) as a plain-int dict.
    On the device plane this is the ONLY transfer, done at the per-K sync."""
    return {f: int(v) for f, v in zip(Counters._fields, c)}


def delta(cur: Dict[str, int], prev: Dict[str, int]) -> Dict[str, int]:
    """Per-round rates from two cumulative snapshots."""
    return {k: cur[k] - prev.get(k, 0) for k in cur}


def update_token_counters(counters: Counters, *, act, aborts, positions,
                          page_size: int, table_before=None,
                          table_after=None) -> Counters:
    """One decode token's worth of in-graph accumulation.

    Called at the end of the serve step / token body with the pre- and
    post-alloc table (when the family is paged).  Derivations, not taps:
    ``need_new`` is recomputed from positions (a lane allocates exactly at
    page boundaries), probe work mirrors ``alloc_step_incremental``'s
    2*need_new host-side note, and tombstone reclamation is the
    ``num_tombs`` drop across the insert (inserts only ever reclaim;
    deletes only ever create — so the sign splits the two counts).
    """
    act_i = act.astype(jnp.int32)
    ab_i = aborts.astype(jnp.int32)
    upd = {
        "abort_events": counters.abort_events + jnp.sum(ab_i),
        "tokens_accepted": counters.tokens_accepted
        + jnp.sum(act_i * (1 - ab_i)),
    }
    if table_before is not None and table_after is not None:
        need_new = ((positions % page_size) == 0).astype(jnp.int32) * act_i
        dk = (table_after.num_keys - table_before.num_keys).astype(jnp.int32)
        dt = (table_before.num_tombs - table_after.num_tombs).astype(
            jnp.int32)
        upd["probe_steps"] = counters.probe_steps + 2 * jnp.sum(need_new)
        upd["pages_allocated"] = counters.pages_allocated + dk
        upd["tombstones_reclaimed"] = (counters.tombstones_reclaimed
                                       + jnp.maximum(dt, 0))
    return counters._replace(**upd)


def note_free(counters: Counters, *, table_before, table_after) -> Counters:
    """Eager (between-round) accounting for ``free_sequences``: the key
    drop is pages freed, the tombstone rise is tombstones created."""
    dk = (table_before.num_keys - table_after.num_keys).astype(jnp.int32)
    dt = (table_after.num_tombs - table_before.num_tombs).astype(jnp.int32)
    return counters._replace(
        pages_freed=counters.pages_freed + jnp.maximum(dk, 0),
        tombstones_created=counters.tombstones_created + jnp.maximum(dt, 0))


# -- host plane -------------------------------------------------------------
#
# Module counters for eager code that has no device state to ride: the
# TableShard migration sweeps, simulator allocs, etc.  Mirrors the
# PROBE_STATS scope idiom so tests/benches can bracket a region.

HOST_COUNTERS: Dict[str, int] = {f: 0 for f in Counters._fields}


def note_host(field: str, n: int) -> None:
    HOST_COUNTERS[field] = HOST_COUNTERS.get(field, 0) + int(n)


@contextlib.contextmanager
def host_counters_scope():
    """Zero the host plane for the ``with`` body; restore (outer + body)
    afterwards so nesting composes additively."""
    outer = dict(HOST_COUNTERS)
    for k in HOST_COUNTERS:
        HOST_COUNTERS[k] = 0
    try:
        yield HOST_COUNTERS
    finally:
        body = dict(HOST_COUNTERS)
        for k in HOST_COUNTERS:
            HOST_COUNTERS[k] = outer.get(k, 0) + body.get(k, 0)
