"""Metrics registry + exporters (tentpole c).

One process-local registry unifies the repo's scattered measurement
surfaces — the device counter plane, ``page_table.PROBE_STATS``,
``kernels/stats.KERNEL_STATS``, scheduler stats and
``engine.fallback_report`` — behind two snapshot exporters:

* ``prometheus_text()``  — Prometheus text exposition format, and
* ``json_snapshot()``    — the same numbers as one JSON object.

There is no HTTP server (no new deps): the ``ContinuousBatcher`` exposes
``metrics_text()`` / ``metrics_json()`` and ``launch/serve.py --metrics-out``
writes both files at drain, which is what CI archives.

Sources are zero-arg callables registered once and re-read at every
snapshot, so scoped module counters (probe/kernel stats) are absorbed
without the registry knowing their lifetime.  String-valued entries (the
fallback report's "ok"/reason fields) become Prometheus *info*-style
series: ``repro_info{key="decode_tp",value="ok"} 1``.
"""
from __future__ import annotations

import json
import math
from typing import Callable, Dict, Mapping, Union

Number = Union[int, float]


def _sanitize(name: str) -> str:
    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


class MetricsRegistry:
    """Counters (monotone), gauges (set), and absorbed sources."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = _sanitize(namespace)
        self._counters: Dict[str, Number] = {}
        self._gauges: Dict[str, Number] = {}
        self._info: Dict[str, str] = {}
        self._sources: Dict[str, Callable[[], Mapping[str, object]]] = {}

    # -- writers -----------------------------------------------------------

    def inc(self, name: str, n: Number = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + n

    def set_gauge(self, name: str, v: Number) -> None:
        self._gauges[name] = v

    def set_info(self, name: str, v: str) -> None:
        self._info[name] = str(v)

    def source(self, name: str,
               fn: Callable[[], Mapping[str, object]]) -> None:
        """Register a zero-arg callable returning {metric: value}; re-read
        at every snapshot.  Numeric values export as gauges, strings as
        info series."""
        self._sources[name] = fn

    # -- snapshot ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        counters = dict(self._counters)
        gauges = dict(self._gauges)
        info = dict(self._info)
        for src, fn in sorted(self._sources.items()):
            try:
                vals = fn()
            except Exception as e:  # a dead source must not kill serving
                info[f"{src}_error"] = repr(e)
                continue
            for k, v in vals.items():
                key = f"{src}_{k}"
                if isinstance(v, bool) or isinstance(v, str):
                    info[key] = str(v)
                elif isinstance(v, (int, float)):
                    gauges[key] = v
                else:
                    info[key] = repr(v)
        return {"counters": counters, "gauges": gauges, "info": info}

    def json_snapshot(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, indent=2,
                          default=str)

    def prometheus_text(self) -> str:
        snap = self.snapshot()
        ns = self.namespace
        lines = []
        for name, v in sorted(snap["counters"].items()):
            m = f"{ns}_{_sanitize(name)}"
            lines.append(f"# TYPE {m} counter")
            lines.append(f"{m} {_fmt(v)}")
        for name, v in sorted(snap["gauges"].items()):
            m = f"{ns}_{_sanitize(name)}"
            lines.append(f"# TYPE {m} gauge")
            lines.append(f"{m} {_fmt(v)}")
        if snap["info"]:
            m = f"{ns}_info"
            lines.append(f"# TYPE {m} gauge")
            for name, v in sorted(snap["info"].items()):
                val = str(v).replace("\\", "\\\\").replace('"', '\\"')
                lines.append(
                    f'{m}{{key="{_sanitize(name)}",value="{val}"}} 1')
        return "\n".join(lines) + "\n"


def _fmt(v: Number) -> str:
    if isinstance(v, float):
        if math.isnan(v):
            return "NaN"
        return repr(v)
    return str(v)
