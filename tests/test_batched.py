"""Tests for the TPU-native batched hash table (core/batched.py) and the
sharded DHT (core/sharded.py)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import batched as BT
from repro.core import encoding as E
from repro.core.baselines import gao_noreuse as GN
from repro.core.spec import (OP_DELETE, OP_INSERT, OP_LOOKUP, RET_ABORT,
                             RET_FALSE, RET_TRUE, step_spec)


def spec_apply_grouped(state, ops, keys, m):
    """Reference: the documented linearization (deletes < inserts < lookups,
    each by batch index), with ABORT when the table genuinely has no space."""
    rets = [None] * len(ops)
    for grp in (OP_DELETE, OP_INSERT, OP_LOOKUP):
        for b, (o, k) in enumerate(zip(ops, keys)):
            if o != grp:
                continue
            if o == OP_INSERT and k not in state and len(state) >= m:
                rets[b] = RET_ABORT
                continue
            state, r = step_spec(state, o, k)
            rets[b] = r
    return state, rets


def table_keys(ht):
    tab = np.asarray(ht.table)
    keys = tab >> 2
    return set(int(k) for k in keys[keys != E.RESERVED_KEY])


@pytest.mark.parametrize("strategy", ["linear", "robinhood", "hopscotch"])
@pytest.mark.parametrize("claim_tombstones", [True, False])
def test_insert_lookup_delete_roundtrip(claim_tombstones, strategy):
    # strategy-parameterized: the ProbeStrategy refactor keeps one
    # observable contract (deeper conformance in test_probe_strategies.py)
    ht = BT.create(64, seed=1, strategy=strategy)
    keys = jnp.arange(10, dtype=jnp.uint32)
    ht, ret = BT.insert_batch(ht, keys, claim_tombstones=claim_tombstones,
                              strategy=strategy)
    assert np.all(np.asarray(ret) == RET_TRUE)
    assert np.all(np.asarray(BT.lookup_batch(ht, keys, strategy=strategy)))
    assert not np.any(np.asarray(BT.lookup_batch(
        ht, jnp.arange(100, 110, dtype=jnp.uint32), strategy=strategy)))
    ht, ret = BT.delete_batch(ht, keys[:5], strategy=strategy)
    assert np.all(np.asarray(ret) == 1)
    present = np.asarray(BT.lookup_batch(ht, keys, strategy=strategy))
    assert not np.any(present[:5]) and np.all(present[5:])
    assert int(ht.num_keys) == 5
    assert int(ht.num_tombs) == (5 if strategy != "hopscotch" else 0)


def test_duplicate_inserts_one_winner():
    """Batch-internal duplicate inserts: exactly one returns true — the
    batched analog of Lemma 4 / 'exactly one copy survives'."""
    ht = BT.create(16)
    keys = jnp.array([7, 7, 7, 7], dtype=jnp.uint32)
    ht, ret = BT.insert_batch(ht, keys)
    ret = np.asarray(ret)
    assert (ret == RET_TRUE).sum() == 1
    assert ret[0] == RET_TRUE  # lowest batch index wins (priority order)
    assert int(ht.num_keys) == 1
    tab = np.asarray(ht.table)
    assert ((tab >> 2) == 7).sum() == 1


def test_tombstone_reuse_vs_noreuse():
    """Churn in a small table: the paper's table reuses tombstones and never
    aborts; the no-reuse baseline fills with tombstones and aborts."""
    m = 8
    ht = BT.create(m)
    gn = GN.create(m)
    gn_aborted = False
    for t in range(m + 1):
        k = jnp.array([1000 + t], dtype=jnp.uint32)
        ht, r1 = BT.insert_batch(ht, k)
        assert int(r1[0]) == RET_TRUE, f"reuse table aborted at churn {t}"
        ht, r2 = BT.delete_batch(ht, k)
        assert int(r2[0]) == 1
        if not gn_aborted:
            gn, g1 = GN.insert_batch(gn, k)
            gn_aborted = int(g1[0]) == RET_ABORT
            if not gn_aborted:
                gn, _ = GN.delete_batch(gn, k)
    assert gn_aborted, "no-reuse baseline should abort under churn"
    assert bool(GN.needs_rebuild(gn, slack=0.9))


def test_abort_when_full_and_rebuild():
    m = 8
    ht = BT.create(m)
    ht, r = BT.insert_batch(ht, jnp.arange(m, dtype=jnp.uint32))
    assert np.all(np.asarray(r) == RET_TRUE)
    ht, r = BT.insert_batch(ht, jnp.array([99], dtype=jnp.uint32))
    assert int(r[0]) == RET_ABORT
    ht2 = BT.rebuild(ht, 32)
    assert table_keys(ht2) == set(range(m))
    ht2, r = BT.insert_batch(ht2, jnp.array([99], dtype=jnp.uint32))
    assert int(r[0]) == RET_TRUE


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 9)),
                min_size=1, max_size=24),
       st.integers(0, 5))
def test_apply_batch_matches_spec(ops_keys, seed):
    """Property: apply_batch == the documented sequential serialization."""
    m = 16
    ht = BT.create(m, seed=seed)
    state = set()
    # split into a few batches
    rng = np.random.default_rng(seed)
    arr = np.array(ops_keys, dtype=np.int64)
    n_batches = rng.integers(1, 4)
    for chunk in np.array_split(arr, n_batches):
        if len(chunk) == 0:
            continue
        ops = jnp.asarray(chunk[:, 0], jnp.int32)
        keys = jnp.asarray(chunk[:, 1], jnp.uint32)
        ht, ret = BT.apply_batch(ht, ops, keys)
        state, expect = spec_apply_grouped(state, list(chunk[:, 0]),
                                           list(chunk[:, 1]), m)
        assert list(np.asarray(ret)) == expect, (chunk, state)
    assert table_keys(ht) == state


def test_no_holes_invariant():
    """Prop 3 analog: every stored key is reachable by probing from h(v)
    without crossing EMPTY (checked via lookup after heavy churn)."""
    rng = np.random.default_rng(0)
    m = 64
    ht = BT.create(m, seed=3)
    live = set()
    for _ in range(30):
        ks = rng.integers(0, 40, size=16).astype(np.uint32)
        ops = rng.integers(1, 3, size=16).astype(np.int32)
        ht, _ = BT.apply_batch(ht, jnp.asarray(ops), jnp.asarray(ks))
        for o, k in zip(ops, ks):
            state_set = live
            if o == OP_INSERT:
                state_set.add(int(k))
            elif o == OP_DELETE:
                state_set.discard(int(k))
    # NOTE: apply_batch order is deletes<inserts, so replay with same order:
    # instead of tracking exactly, just verify lookup self-consistency:
    assert table_keys(ht) == {int(k) for k in
                              np.asarray(jnp.arange(40, dtype=jnp.uint32))
                              [np.asarray(BT.lookup_batch(
                                  ht, jnp.arange(40, dtype=jnp.uint32)))]}


def test_counts_track_state():
    rng = np.random.default_rng(5)
    ht = BT.create(128, seed=2)
    for _ in range(10):
        ks = jnp.asarray(rng.integers(0, 60, size=32), jnp.uint32)
        ops = jnp.asarray(rng.integers(0, 3, size=32), jnp.int32)
        ht, _ = BT.apply_batch(ht, ops, ks)
    assert int(ht.num_keys) == len(table_keys(ht))
    tab = np.asarray(ht.table)
    assert int(ht.num_tombs) == int((tab == E.TOMBSTONE).sum())


SHARD_TEST = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import sharded as SH
from repro.core.spec import OP_INSERT, OP_DELETE, OP_LOOKUP, step_spec

mesh = Mesh(np.array(jax.devices()).reshape(8), ("model",))
st, apply_fn = SH.make_sharded_table(mesh, "model", m_global=8 * 64,
                                     capacity=32, seed=0)
rng = np.random.default_rng(0)
state = set()
for it in range(6):
    B = 8 * 16
    ops = rng.integers(0, 3, size=B).astype(np.int32)
    keys = rng.integers(0, 200, size=B).astype(np.uint32)
    st, ret, ovf = apply_fn(st, jnp.asarray(ops), jnp.asarray(keys))
    ret = np.asarray(ret); ovf = np.asarray(ovf)
    assert not ovf.any(), "unexpected overflow"
    # reference: group by (shard, op-kind) — within one batch the DHT applies
    # deletes<inserts<lookups per shard; keys are single-owner so the global
    # order across shards is a valid interleaving. Verify per-key end state.
    for grp in (OP_DELETE, OP_INSERT, OP_LOOKUP):
        for b in range(B):
            if ops[b] != grp: continue
            state, r = step_spec(state, int(ops[b]), int(keys[b]))
            assert int(ret[b]) == r, (it, b, ops[b], keys[b], int(ret[b]), r)
print("SHARDED-OK")
"""


def test_sharded_dht_8dev():
    """Run the DHT on 8 forced host devices in a subprocess (keeps this
    process at 1 device)."""
    r = subprocess.run([sys.executable, "-c", SHARD_TEST],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "SHARDED-OK" in r.stdout, r.stdout + r.stderr
