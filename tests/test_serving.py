"""Serving tests: paged decode == full forward for every family; page-table
allocator invariants (tombstone reuse under eviction churn); engine state
plumbing; the fused manual-TP decode region on a 1-wide mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core import batched as BT
from repro.dist.sharding import serve_manual_rules
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT

LPT = PT.for_strategy("linear")  # the strategy-bound facade

DECODE_ARCHS = ["qwen2.5-32b", "qwen1.5-32b", "codeqwen1.5-7b",
                "granite-moe-1b-a400m", "qwen3-moe-235b-a22b",
                "gemma3-12b", "mamba2-2.7b", "zamba2-1.2b", "qwen2-vl-7b",
                "seamless-m4t-large-v2"]


def _fill_cross_kv(cfg, params, state, memory):
    def one_layer(lp):
        cp = lp["cross"]
        k = jnp.einsum("bsd,dhk->bshk", memory, cp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, cp["wv"])
        if "bk" in cp:
            k, v = k + cp["bk"], v + cp["bv"]
        return k, v
    ck, cv = jax.vmap(one_layer)(params["decoder"])
    state["cross_k"], state["cross_v"] = ck, cv
    return state


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init(cfg, key)
    B, T = 2, 12
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    kw = {}
    state, _ = EG.make_decode_state(cfg, B, S_max=64, page_size=8)
    if cfg.family == "vlm":
        kw["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(T)[None, None], (3, B, T)).astype(jnp.int32)
    if cfg.family == "encdec":
        src = jax.random.normal(key, (B, 8, cfg.d_model),
                                cfg.activation_dtype())
        kw["src_embeds"] = src
        memory = model.encode(cfg, params, src)
        state = _fill_cross_kv(cfg, params, state, memory)
    ref, _ = model.forward(cfg, params, tokens, **kw)
    step = jax.jit(EG.make_serve_step(cfg, S_max=64, page_size=8))
    errs = []
    for t in range(T):
        pos = jnp.full((B,), t, jnp.int32)
        args = (params, state, tokens[:, t:t + 1], pos)
        if cfg.family == "vlm":
            args += (jnp.full((3, B, 1), t, jnp.int32),)
        logits, state = step(*args)
        errs.append(float(jnp.max(jnp.abs(
            logits - ref[:, t].astype(jnp.float32)))))
    assert max(errs) < 6e-2, (arch, errs)   # bf16 accumulation tolerance


def _mesh_1x1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "granite-moe-1b-a400m",
                                  "qwen2-vl-7b", "gemma3-12b",
                                  "zamba2-1.2b"])
def test_manual_decode_single_device_matches_reference(arch):
    """``tp_impl="manual"`` on a 1-wide model axis routes through the fused
    manual shard_map region (decode_manual_tp deliberately allows tp == 1)
    and must match the no-rules single-device decode numerically."""
    cfg = dataclasses.replace(get_smoke_config(arch), tp_impl="manual")
    rules = serve_manual_rules(_mesh_1x1())
    assert EG._manual_decode_ok(cfg, rules)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, T = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)

    def run(r):
        state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4,
                                        rules=r)
        step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4,
                                          rules=r))
        outs = []
        for t in range(T):
            pos = jnp.full((B,), t, jnp.int32)
            args = (params, state, toks[:, t:t + 1], pos)
            if cfg.family == "vlm":
                args += (jnp.full((3, B, 1), t, jnp.int32),)
            lg, state = step(*args)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    np.testing.assert_allclose(run(rules), run(None), atol=5e-2, rtol=1e-2)


def test_manual_decode_gate_and_fallback_reasons():
    """After the universal fused decode, only genuinely unsupported shapes
    fall back (ssm: attention-free; encdec: cross-attn state) — and every
    fallback carries a loggable reason, never a silent swallow.  gemma3
    (local-window) and zamba2 (hybrid) now PASS the gate."""
    rules = serve_manual_rules(_mesh_1x1())
    gemma = dataclasses.replace(get_smoke_config("gemma3-12b"),
                                tp_impl="manual")
    assert gemma.pattern_local and EG._manual_decode_ok(gemma, rules)
    hybrid = dataclasses.replace(get_smoke_config("zamba2-1.2b"),
                                 tp_impl="manual")
    assert EG._manual_decode_ok(hybrid, rules)
    ssm = dataclasses.replace(get_smoke_config("mamba2-2.7b"),
                              tp_impl="manual")
    assert not EG._manual_decode_ok(ssm, rules)
    assert "SSM" in EG._manual_decode_reason(ssm, rules)
    encdec = dataclasses.replace(get_smoke_config("seamless-m4t-large-v2"),
                                 tp_impl="manual")
    assert not EG._manual_decode_ok(encdec, rules)
    assert "cross-attention" in EG._manual_decode_reason(encdec, rules)
    # gspmd impl never takes the fused path
    dense = get_smoke_config("qwen2.5-32b")
    assert not EG._manual_decode_ok(dense, rules)
    assert "manual" in EG._manual_decode_reason(dense, rules)


MEGA_CASES = [("qwen2.5-32b", {}), ("granite-moe-1b-a400m", {}),
              ("qwen2.5-32b", {"kv_cache_dtype": "int8"}),
              ("gemma3-12b", {}), ("zamba2-1.2b", {})]


def _drive_single(cfg, params, state, tok, step, K):
    """Reference driver: K jitted single steps + host-side greedy sampling
    (exactly what the megastep fuses in-graph)."""
    B = tok.shape[0]
    toks = []
    for _ in range(K):
        pos = state["pos"]
        args = (params, state, tok, pos)
        if cfg.family == "vlm":
            args += (jnp.broadcast_to(pos[None, :, None],
                                      (3, B, 1)).astype(jnp.int32),)
        logits, state = step(*args)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        tok = jnp.where(state["aborted"][:, None], tok, nxt)
        toks.append(np.asarray(tok[:, 0]))
    return np.stack(toks, axis=1), state


def _assert_state_bitwise(a, b):
    mism = [k for k in a
            if not all(jax.tree.leaves(jax.tree.map(
                lambda x, y: bool(np.array_equal(np.asarray(x),
                                                 np.asarray(y))),
                a[k], b[k])))]
    assert not mism, f"state leaves diverged: {mism}"


@pytest.mark.parametrize("arch,over", MEGA_CASES)
def test_megastep_matches_single_steps(arch, over):
    """K=8 megastep == 8 single steps, BITWISE: same greedy tokens, same
    final state (pools included) — the scan dispatch may not change a single
    bit of the decode."""
    cfg = dataclasses.replace(get_smoke_config(arch), **over)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, K = 2, 8
    tok0 = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                              cfg.vocab_size)
    state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4)
    step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4))
    ref_toks, ref_state = _drive_single(cfg, params, dict(state), tok0,
                                        step, K)

    state2, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4)
    mega = jax.jit(EG.make_serve_megastep(cfg, S_max=32, K=K, page_size=4))
    mtoks, mstate = mega(params, state2, tok0)
    np.testing.assert_array_equal(np.asarray(mtoks), ref_toks)
    _assert_state_bitwise(ref_state, mstate)
    if "table" in mstate:
        assert int(LPT.verify_block_table(
            mstate["table"], mstate["seq_ids"], mstate["pos"],
            mstate["block_table"], page_size=4)) == 0


def test_megastep_abort_latch_and_resume():
    """Abort mid-megastep: the lane latches at the right token (pos frozen,
    pending token = the refused one, trailing outputs frozen), and after the
    §4.3 rebuild the next megastep re-issues the refused suffix — the full
    8-token stream matches a single-step driver that rebuilds and retries
    the moment the abort surfaces.  Also exercises the in-graph done latch
    (``stop_len``)."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, page_size, K = 2, 4, 8                          # S_max=8 -> maxP=2
    step = jax.jit(EG.make_serve_step(cfg, S_max=8, page_size=page_size))
    mega = jax.jit(EG.make_serve_megastep(cfg, S_max=8, K=K,
                                          page_size=page_size))
    state, _ = EG.make_decode_state(cfg, B, S_max=8, page_size=page_size)
    n_pages = state["pools"].k.shape[1]                # 6

    # shared prefix: fill 4 of 6 pages, then re-admit WITHOUT evicting
    # (stale pages stay live — the scenario slack cannot absorb)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(8):
        logits, state = step(params, state, tok, state["pos"])
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    state = dict(state)
    state["seq_ids"] = state["seq_ids"] + B
    state["pos"] = jnp.zeros((B,), jnp.int32)
    tok0 = jnp.zeros((B, 1), jnp.int32)

    # PATH A: single steps, rebuild immediately when the abort surfaces
    stA, tokA, streamA, rebuildsA = dict(state), tok0, [], 0
    while len(streamA) < 8:
        logits, st2 = step(params, stA, tokA, stA["pos"])
        if bool(np.asarray(st2["aborted"]).any()):
            assert rebuildsA == 0
            stA = EG.rebuild_page_table(st2, n_pages=n_pages * 2)
            rebuildsA += 1
            continue                                   # re-issue, same pos
        tokA = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        streamA.append(np.asarray(tokA[:, 0]))
        stA = st2
    streamA = np.stack(streamA, axis=1)
    assert rebuildsA == 1

    # PATH B: one megastep aborts at token index 4 and latches
    toksB1, stB = mega(params, dict(state), tok0)
    assert np.asarray(stB["aborted"]).all(), "abort not latched"
    assert (np.asarray(stB["pos"]) == 4).all(), "latched at wrong token"
    t1 = np.asarray(toksB1)
    np.testing.assert_array_equal(                      # suffix frozen at
        t1[:, 4:], np.broadcast_to(t1[:, 3:4], (B, 4)))  # the refused token
    stB = EG.rebuild_page_table(stB, n_pages=n_pages * 2)
    assert not np.asarray(stB["aborted"]).any()
    # refused suffix re-issued: feed the pending token; stop_len latches the
    # lanes done in-graph at pos 8 (S_max) instead of overshooting
    toksB2, stB = mega(params, stB, toksB1[:, -1:],
                       jnp.full((B,), 8, jnp.int32))
    assert (np.asarray(stB["pos"]) == 8).all()
    assert not np.asarray(stB["active"]).any(), "done not latched in-graph"
    streamB = np.concatenate([t1[:, :4], np.asarray(toksB2)[:, :4]], axis=1)
    np.testing.assert_array_equal(streamB, streamA)


def test_block_table_evict_readmit_invalidation():
    """Evict -> re-admit must invalidate the cached block-table row: without
    invalidation the re-admitted slot would read a reclaimed physical page
    (stale slot); with it the cache stays coherent with the wait-free
    lookup at every step."""
    n_pages, B, page_size, maxP = 16, 2, 2, 4
    table = LPT.create_table(n_pages)
    seq = jnp.arange(B, dtype=jnp.int32)
    bt = jnp.full((B, maxP), -1, jnp.int32)
    for pos in range(6):
        (table, ws, ab), bt = LPT.alloc_step_incremental(
            table, seq, jnp.full((B,), pos, jnp.int32), bt,
            page_size=page_size)
        assert (np.asarray(ws) >= 0).all() and not np.asarray(ab).any()
    stale_row = np.asarray(bt[0]).copy()
    assert (stale_row[:3] >= 0).all()
    # evict lane 0; its pages become tombstones, immediately reclaimable
    table = LPT.free_sequences(table, seq, jnp.full((B,), 6, jnp.int32),
                              page_size=page_size, max_pages=maxP,
                              active=jnp.asarray([True, False]))
    bt = LPT.invalidate_block_rows(bt, jnp.asarray([True, False]))
    assert (np.asarray(bt[0]) == -1).all()
    assert (np.asarray(bt[1]) == np.asarray(
        LPT.rebuild_block_table(table, seq, maxP))[1]).all()
    # re-admit lane 0 with a fresh sequence id; had the stale row survived,
    # verify_block_table would flag it as soon as its pages went live
    seq = seq.at[0].set(B)
    stale_bt = bt.at[0].set(jnp.asarray(stale_row))
    for pos in range(6):
        p = jnp.full((B,), pos, jnp.int32)
        (table, ws, ab), bt = LPT.alloc_step_incremental(
            table, seq, p, bt, page_size=page_size)
        assert (np.asarray(ws) >= 0).all() and not np.asarray(ab).any()
        assert int(LPT.verify_block_table(table, seq, p, bt,
                                         page_size=page_size)) == 0
    # the hazard is real: the un-invalidated row disagrees with the lookup
    assert int(LPT.verify_block_table(
        table, seq, jnp.full((B,), 0, jnp.int32), stale_bt,
        page_size=page_size)) > 0


def test_block_table_matches_wait_free_lookup_under_churn():
    """CI verification mode under allocator churn (admit / decode / evict /
    reclaim): the incremental cache equals the authoritative wait-free
    lookup after every step, while probing ~page_size x fewer keys."""
    n_pages, B, page_size, maxP = 64, 4, 4, 8
    rng = np.random.default_rng(0)
    table = LPT.create_table(n_pages)
    seq = np.arange(B, dtype=np.int32)
    pos = np.zeros(B, np.int32)
    next_id = B
    bt = jnp.full((B, maxP), -1, jnp.int32)
    PT.probe_stats_reset()
    for round_ in range(40):
        (table, ws, ab), bt = LPT.alloc_step_incremental(
            table, jnp.asarray(seq), jnp.asarray(pos), bt,
            page_size=page_size)
        assert not np.asarray(ab).any()
        pos += 1
        assert int(LPT.verify_block_table(
            table, jnp.asarray(seq), jnp.asarray(pos - 1), bt,
            page_size=page_size)) == 0
        if round_ % 7 == 6:                 # evict a random lane, re-admit
            v = int(rng.integers(B))
            mask = np.zeros(B, bool)
            mask[v] = True
            table = LPT.free_sequences(
                table, jnp.asarray(seq), jnp.asarray(pos),
                page_size=page_size, max_pages=maxP,
                active=jnp.asarray(mask))
            bt = LPT.invalidate_block_rows(bt, jnp.asarray(mask))
            seq[v] = next_id
            next_id += 1
            pos[v] = 0
            bt = jnp.where(jnp.asarray(mask)[:, None],
                           LPT.rebuild_block_table(table, jnp.asarray(seq),
                                                  maxP), bt)
            assert int(LPT.verify_block_table(
                table, jnp.asarray(seq), jnp.asarray(pos), bt,
                page_size=page_size)) == 0


def test_batcher_megastep_churn():
    """End-to-end continuous batching on megasteps with the CI block-table
    verification enabled: evictions + re-admissions over several rounds,
    cache never diverges, one host sync per K tokens."""
    from repro.launch.serve import ContinuousBatcher
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    srv = ContinuousBatcher(cfg, params, batch=4, max_len=24, page_size=4,
                            megastep_k=4, verify_block_table=True)
    for _ in range(8):
        srv.decode_round(8)
    assert srv.evictions > 0
    st = srv.table_stats()
    assert int(st.live_pages) + int(st.tombstones) <= \
        srv.state["pools"].k.shape[1]
    # the proactive scheduler must keep the default (non-overcommitted)
    # pool out of ABORT entirely, and its per-round stats must carry the
    # scoped probe counter (PROBE_STATS lifecycle satellite)
    assert srv.sched.stats.aborts == 0
    assert len(srv.sched.rounds) == 16
    assert any(rs.keys_probed > 0 for rs in srv.sched.rounds)


def test_page_allocator_tombstone_reuse():
    """Evicted sequences' page slots are re-claimed in place: after heavy
    churn, live+tombstone occupancy stays bounded and allocation never
    aborts — the paper's Prop. 2 as a memory allocator."""
    n_pages = 64
    table = LPT.create_table(n_pages)
    page_size = 4
    maxP = 8
    rng = np.random.default_rng(0)
    active = {}   # seq_id -> position
    next_id = 0
    for round_ in range(30):
        # admit until ~75% pool
        while len(active) < 6:
            active[next_id] = 0
            next_id += 1
        seq = jnp.asarray(sorted(active), jnp.int32)
        pos = jnp.asarray([active[int(s)] for s in seq], jnp.int32)
        table, slots, aborted = LPT.alloc_step(table, seq, pos,
                                              page_size=page_size)
        assert (np.asarray(slots) >= 0).all(), "allocator aborted"
        assert not np.asarray(aborted).any()
        for s in np.asarray(seq):
            active[int(s)] += 1
        # evict sequences that got long
        done = [s for s, p in active.items() if p >= rng.integers(8, 24)]
        if done:
            dseq = jnp.asarray(done, jnp.int32)
            dpos = jnp.asarray([active[s] for s in done], jnp.int32)
            table = LPT.free_sequences(table, dseq, dpos,
                                      page_size=page_size, max_pages=maxP)
            for s in done:
                del active[s]
        assert int(table.num_keys) + int(table.num_tombs) <= n_pages
    # table survived 30 rounds of churn without rebuild
    # pages for a sequence at next-write position p: ceil(p / page_size)
    live = sum(-(-p // page_size) for p in active.values())
    assert int(table.num_keys) == live


def test_lookup_pages_consistency():
    table = LPT.create_table(32)
    seq = jnp.arange(3, dtype=jnp.int32)
    for pos in range(10):
        table, ws, _ = LPT.alloc_step(table, seq,
                                     jnp.full((3,), pos, jnp.int32),
                                     page_size=4)
    slots = LPT.lookup_pages(table, seq, jnp.full((3,), 9, jnp.int32),
                            page_size=4, max_pages=8)
    s = np.asarray(slots)
    assert (s[:, :3] >= 0).all()        # pages 0..2 live (pos 9 -> page 2)
    assert (s[:, 3:] == -1).all()       # beyond current position
    flat = s[s >= 0]
    assert len(set(flat.tolist())) == len(flat), "duplicate physical pages"


@settings(max_examples=20, deadline=None)
@given(psize=st.sampled_from([2, 4, 8]),
       steps=st.integers(1, 30),
       B=st.integers(1, 4))
def test_alloc_monotone_pages(psize, steps, B):
    """Each sequence owns exactly ceil(pos/psize) pages, all distinct."""
    n_pages = 256
    table = LPT.create_table(n_pages)
    seq = jnp.arange(B, dtype=jnp.int32)
    for pos in range(steps):
        table, _, _ = LPT.alloc_step(table, seq,
                                    jnp.full((B,), pos, jnp.int32),
                                    page_size=psize)
    expect = -(-steps // psize)
    assert int(table.num_keys) == B * expect
    slots = LPT.lookup_pages(table, seq, jnp.full((B,), steps - 1, jnp.int32),
                            page_size=psize, max_pages=64)
    s = np.asarray(slots)
    live = s[s >= 0]
    assert len(live) == B * expect
    assert len(set(live.tolist())) == len(live)


def test_page_pool_exhaustion_lifecycle():
    """Adversarial allocator lifecycle, under jit: fill the pool to
    exhaustion — the ABORT must be *surfaced* (aborted flag, write_slot
    refused as -1, never wrapped into a valid page) — then evict half the
    sequences and verify the very next alloc_steps re-claim the tombstoned
    slots (Proposition 2 operating as the allocator), with write_slot >= 0
    throughout the reclaim."""
    import functools
    n_pages, B, page_size = 16, 4, 2
    step = jax.jit(functools.partial(LPT.alloc_step, page_size=page_size))
    table = LPT.create_table(n_pages)
    seq = jnp.arange(B, dtype=jnp.int32)
    steps_to_fill = (n_pages // B) * page_size          # 8 -> pool full
    for pos in range(steps_to_fill):
        table, ws, ab = step(table, seq, jnp.full((B,), pos, jnp.int32))
        assert (np.asarray(ws) >= 0).all() and not np.asarray(ab).any()
    assert int(table.num_keys) == n_pages               # every cell live
    # the next boundary must ABORT on every lane — reported, not wrapped
    table, ws, ab = step(table, seq,
                         jnp.full((B,), steps_to_fill, jnp.int32))
    assert np.asarray(ab).all(), "abort not surfaced"
    assert (np.asarray(ws) == -1).all(), "wrapped write_slot"
    # evict half -> tombstones; freed slots are re-claimable IMMEDIATELY
    freed = np.asarray(LPT.lookup_pages(
        table, seq[:2], jnp.full((2,), steps_to_fill - 1, jnp.int32),
        page_size=page_size, max_pages=n_pages))
    table = LPT.free_sequences(table, seq[:2],
                              jnp.full((2,), steps_to_fill, jnp.int32),
                              page_size=page_size, max_pages=n_pages)
    assert int(table.num_tombs) == n_pages // 2
    fresh = jnp.arange(B, B + 2, dtype=jnp.int32)
    for pos in range(steps_to_fill):
        table, ws, ab = step(table, fresh, jnp.full((2,), pos, jnp.int32))
        assert (np.asarray(ws) >= 0).all(), "reclaim failed"
        assert not np.asarray(ab).any()
        if pos % page_size == 0:
            assert set(np.asarray(ws).tolist()) <= set(
                freed[freed >= 0].tolist()), "did not reuse tombstones"
    assert int(table.num_tombs) == 0                    # all reclaimed


def test_engine_abort_refusal_and_rebuild():
    """End-to-end §4.3: exhaust the pool (sequences re-admitted without
    eviction — the scenario page slack cannot absorb), verify the engine
    latches ``aborted`` and refuses the token (pos frozen, no silent
    wrap/drop), then ``rebuild_page_table`` into a larger pool (table
    re-hashed AND physical pages moved to the keys' new slots) and the
    retried step must match a big-pool reference run bit-for-nearly."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, page_size = 2, 4                                  # maxP = 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0,
                                cfg.vocab_size)
    step = jax.jit(EG.make_serve_step(cfg, S_max=8, page_size=page_size))
    state, _ = EG.make_decode_state(cfg, B, S_max=8, page_size=page_size)
    n_pages = state["pools"].k.shape[1]                  # 6
    # big-pool reference with IDENTICAL maxP: rebuild (on a healthy state)
    # into 4x the pages — also covers rebuild without any abort
    ref_state = EG.rebuild_page_table(dict(state), n_pages=n_pages * 4)

    def both(t):
        nonlocal state, ref_state
        pos = jnp.full((B,), t, jnp.int32)
        lg, state = step(params, state, tokens[:, t:t + 1], pos)
        rlg, ref_state = step(params, ref_state, tokens[:, t:t + 1], pos)
        return np.asarray(lg), np.asarray(rlg)

    for t in range(8):                                   # 4 of 6 pages
        lg, rlg = both(t)
        np.testing.assert_allclose(lg, rlg, atol=2e-4, rtol=1e-4)
    assert not np.asarray(state["aborted"]).any()
    # re-admit both slots WITHOUT evicting (stale pages stay live)
    for s in (state, ref_state):
        s["seq_ids"] = s["seq_ids"] + B
        s["pos"] = jnp.zeros((B,), jnp.int32)
    lg, rlg = both(0)                                    # 6 of 6 pages
    np.testing.assert_allclose(lg, rlg, atol=2e-4, rtol=1e-4)
    for t in range(1, 4):
        lg, rlg = both(t)
    # t=4 page boundary: the small pool is full -> ABORT, token refused
    lg, rlg = both(4)
    assert np.asarray(state["aborted"]).all(), "abort not surfaced"
    assert (np.asarray(state["pos"]) == 4).all(), "token not refused"
    assert (np.asarray(ref_state["pos"]) == 5).all()
    # §4.3 rebuild: 2x pool, pages follow their keys; flags cleared
    state = EG.rebuild_page_table(state, n_pages=n_pages * 2)
    assert not np.asarray(state["aborted"]).any()
    assert state["pools"].k.shape[1] == n_pages * 2
    # retry the refused token against the reference's stored step, then
    # decode on in lockstep
    pos = jnp.full((B,), 4, jnp.int32)
    lg2, state = step(params, state, tokens[:, 4:5], pos)
    np.testing.assert_allclose(np.asarray(lg2), rlg, atol=2e-4, rtol=1e-4)
    assert (np.asarray(state["pos"]) == 5).all()
    for t in range(5, 7):
        lg, rlg = both(t)
        np.testing.assert_allclose(lg, rlg, atol=2e-4, rtol=1e-4)


def test_inactive_lanes_leak_no_pages():
    """Phantom-page fix: a finished (inactive) lane must stop allocating
    pages and its pos must freeze, while live lanes decode on."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, page_size = 4, 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 12), 0,
                                cfg.vocab_size)
    step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=page_size))
    state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=page_size)
    state["active"] = jnp.asarray([True, True, False, False])
    for t in range(8):
        pos = state["pos"]
        _, state = step(params, state, tokens[:, t:t + 1], pos)
    assert (np.asarray(state["pos"]) == [8, 8, 0, 0]).all()
    # only the two live lanes own pages: 8 steps @ page_size 2 -> 4 each
    assert int(state["table"].num_keys) == 2 * 4


def test_decode_state_after_eviction_reuse():
    """End-to-end: decode, evict, re-admit — logits of the new sequence are
    unaffected by the stale pages it reclaimed."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, T = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4))

    # run seq ids (0,1) for T steps, evict, re-admit as (2,3), rerun
    state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4)
    ref_logits = None
    for t in range(T):
        pos = jnp.full((B,), t, jnp.int32)
        logits, state = step(params, state, tokens[:, t:t + 1], pos)
        if ref_logits is None:
            ref_logits = logits
    state["table"] = LPT.free_sequences(
        state["table"], state["seq_ids"], jnp.full((B,), T, jnp.int32),
        page_size=4, max_pages=8)
    state["seq_ids"] = state["seq_ids"] + B
    logits2, _ = step(params, state, tokens[:, 0:1],
                      jnp.zeros((B,), jnp.int32))
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(ref_logits),
                               atol=1e-4)
