"""Training-substrate tests: checkpoint atomicity/elastic restore, restart
determinism, optimizer, gradient compression, dedup data pipeline, fault
tolerance policies."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dist import compression as COMP
from repro.dist import fault_tolerance as FT
from repro.training import checkpoint as CKPT
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train_step import init_state, make_train_step


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("qwen2.5-32b")
    state, axes = init_state(cfg, jax.random.PRNGKey(0))
    path = CKPT.save(str(tmp_path), 7, state, axes)
    assert path.endswith("step_00000007")
    restored, step = CKPT.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_prune_and_latest(tmp_path):
    cfg = get_smoke_config("mamba2-2.7b")
    state, axes = init_state(cfg, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        CKPT.save(str(tmp_path), s, state, axes)
    CKPT.prune(str(tmp_path), keep=2)
    assert CKPT.latest_step(str(tmp_path)) == 4
    assert sorted(os.listdir(tmp_path)) == ["step_00000003",
                                            "step_00000004"]


def test_restart_determinism(tmp_path):
    """Crash/restart reproduces the uninterrupted run exactly: batches are a
    pure function of step, checkpoints capture all state."""
    cfg = get_smoke_config("codeqwen1.5-7b")
    step_fn = jax.jit(make_train_step(cfg))

    def run(state, start, n):
        losses = []
        for i in range(start, start + n):
            b = D.synth_batch(cfg, batch=2, seq_len=16, step=i)
            state, m = step_fn(state, b)
            losses.append(float(m["loss"]))
        return state, losses

    s0, axes = init_state(cfg, jax.random.PRNGKey(0))
    _, full = run(s0, 0, 6)

    s1, _ = init_state(cfg, jax.random.PRNGKey(0))
    s1, first = run(s1, 0, 3)
    CKPT.save(str(tmp_path), 3, s1, axes)
    s2, _ = init_state(cfg, jax.random.PRNGKey(0))
    s2, start = CKPT.restore(str(tmp_path), s2)
    _, second = run(s2, start, 3)
    np.testing.assert_allclose(first + second, full, rtol=1e-6)


def test_adamw_decreases_loss_quadratic():
    cfg = OPT.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = OPT.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}      # d/dw ||w||^2
        params, opt, _ = OPT.apply(cfg, params, opt, grads)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clipping():
    cfg = OPT.AdamWConfig(clip_norm=1.0)
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(OPT.global_norm(clipped)) - 1.0) < 1e-5


def test_compression_quantize_roundtrip():
    x = np.random.default_rng(0).normal(size=(5000,)).astype(np.float32)
    q, scale = COMP._quantize(jnp.asarray(x))
    back = COMP._dequantize(q, scale, x.shape[0])
    err = np.abs(np.asarray(back) - x)
    blk_scale = np.abs(x).max() / 127
    assert err.max() <= blk_scale * 1.01


def test_compression_error_feedback_reduces_bias():
    """With error feedback, the *accumulated* quantization error stays
    bounded (residual carried, not lost)."""
    rng = np.random.default_rng(1)
    err = jnp.zeros((1024,), jnp.float32)
    total_in, total_out = 0.0, 0.0
    for i in range(20):
        g = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32)) * 1e-3
        x32 = g + err
        q, scale = COMP._quantize(x32)
        sent = COMP._dequantize(q, scale, 1024)
        err = x32 - sent
        total_in += float(jnp.sum(g))
        total_out += float(jnp.sum(sent))
    # everything not yet sent is still in the residual
    assert abs(total_in - (total_out + float(jnp.sum(err)))) < 1e-3


def test_dedup_filters_duplicates():
    cfg = get_smoke_config("qwen2.5-32b")
    dd = D.DedupState(m=1 << 12, window=8)
    b = D.synth_batch(cfg, batch=4, seq_len=64, step=0)
    keep1, frac1 = dd.filter_batch(b["tokens"])
    assert bool(keep1.all())
    keep2, frac2 = dd.filter_batch(b["tokens"])     # identical resubmission
    assert not bool(keep2.any())
    assert float(frac2) > 0.9


def test_straggler_monitor():
    mon = FT.StragglerMonitor(threshold=2.0, patience=2)
    verdicts = [mon.observe(i, 1.0) for i in range(5)]
    assert set(verdicts) == {"ok"}
    assert mon.observe(5, 5.0) == "straggler"
    assert mon.observe(6, 5.0) == "replan"
    assert mon.observe(7, 1.0) == "ok"


def test_watchdog_fires():
    wd = FT.StepWatchdog(deadline_s=0.0)
    wd.arm(3)
    with pytest.raises(FT.WatchdogTimeout):
        import time
        time.sleep(0.01)
        wd.check()


def test_elastic_plan():
    shape, axes = FT.elastic_plan(512, model_parallel=16)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes = FT.elastic_plan(240, model_parallel=16)  # lost a host
    assert shape == (15, 16) and axes == ("data", "model")
    assert FT.accum_for(256, 240) == 2


def test_save_resave_merges_extra(tmp_path):
    """Re-saving a committed step with changed ``extra`` metadata must land
    it (atomically) instead of silently dropping it — the shard-manifest
    re-commit after an elastic remesh depends on this."""
    state = {"w": jnp.zeros((2,))}
    CKPT.save(str(tmp_path), 3, state, extra={"manifest": [0, 1]})
    path = CKPT.save(str(tmp_path), 3, state, extra={"manifest": [0, 0]})
    import json
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    assert man["extra"]["manifest"] == [0, 0]
    # leaves untouched (restart determinism): re-save is metadata-only
    restored, _ = CKPT.restore(str(tmp_path), state, step=3)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.zeros(2))


def test_sharded_checkpoint_commit_protocol(tmp_path):
    """save_shard is invisible until commit_sharded lands shards.json; the
    committed step round-trips every shard's payload + extras, and a
    re-commit with a new shard manifest replaces it atomically."""
    CKPT.save_shard(str(tmp_path), 4, 0, {"keys": np.arange(3, dtype=np.uint32)},
                    extra={"n_cells": 32})
    CKPT.save_shard(str(tmp_path), 4, 1, {"keys": np.arange(5, dtype=np.uint32)})
    assert CKPT.latest_sharded_step(str(tmp_path)) is None   # not committed
    CKPT.commit_sharded(str(tmp_path), 4,
                        shard_manifest={"prefix_bits": 1, "owners": [0, 1]})
    assert CKPT.latest_sharded_step(str(tmp_path)) == 4
    shards, man, step = CKPT.restore_sharded(str(tmp_path))
    assert step == 4 and man["owners"] == [0, 1]
    assert [s["keys"].size for s in shards] == [3, 5]
    assert shards[0]["_extra"]["n_cells"] == 32
    # re-commit with a reassigned manifest (post-remesh re-save path)
    CKPT.commit_sharded(str(tmp_path), 4,
                        shard_manifest={"prefix_bits": 1, "owners": [0, 0]})
    _, man, _ = CKPT.restore_sharded(str(tmp_path))
    assert man["owners"] == [0, 0]


def test_elastic_table_plan_agrees_with_manifest():
    """The two halves of elastic recovery describe the same fleet: the
    surviving mesh's host-group count == the reassigned manifest's live
    shard count."""
    from repro.dist.table_shard import ShardManifest
    man = ShardManifest.balanced(4)
    new_man, shape, names = FT.elastic_table_plan(man, lost_shard=1,
                                                  model_parallel=16)
    assert len(new_man.live_shards()) == 3
    assert names == ("pod", "data", "model") and shape[0] == 3
    assert shape[0] * shape[1] * shape[2] == 3 * FT.POD_CHIPS
    # down to one surviving group the pod axis collapses into data
    one = ShardManifest.balanced(2)
    new_man, shape, names = FT.elastic_table_plan(one, lost_shard=1,
                                                  model_parallel=16)
    assert len(new_man.live_shards()) == 1
    assert names == ("data", "model") and shape == (16, 16)
