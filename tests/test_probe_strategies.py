"""ProbeStrategy conformance suite (core/probe_strategies.py).

Every strategy must satisfy the same observable contract (the documented
by-batch-index serialization, exact counters, wait-free lookups); the
``linear`` strategy is additionally pinned BITWISE to the pre-refactor
implementation via recorded-trace digests (tests/fixtures/); ``hopscotch``
is additionally pinned to zero tombstones under churn.
"""
import importlib.util
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as BT
from repro.core import encoding as E
from repro.core.linearizability import check_history
from repro.core.probe_strategies import (H_NEIGHBORHOOD, STRATEGIES,
                                         get_strategy)
from repro.core.spec import (OP_DELETE, OP_INSERT, OP_LOOKUP, RET_ABORT,
                             RET_TRUE, step_spec)
from repro.serving import page_table as PT

ALL = sorted(STRATEGIES)


def spec_apply_grouped(state, ops, keys, m):
    """Reference serialization: deletes < inserts < lookups, each by batch
    index; ABORT when the table genuinely has no space.  Exact for linear /
    robinhood at any m and for hopscotch when m <= H (the neighborhood
    covers the table, so inserts abort only on a truly full table)."""
    rets = [None] * len(ops)
    for grp in (OP_DELETE, OP_INSERT, OP_LOOKUP):
        for b, (o, k) in enumerate(zip(ops, keys)):
            if o != grp:
                continue
            if o == OP_INSERT and k not in state and len(state) >= m:
                rets[b] = RET_ABORT
                continue
            state, r = step_spec(state, o, k)
            rets[b] = r
    return state, rets


def table_keys(ht):
    tab = np.asarray(ht.table)
    keys = tab >> 2
    return set(int(k) for k in keys[keys != E.RESERVED_KEY])


def home_of(ht, key):
    return int(BT._hash(ht, jnp.array([key], jnp.uint32))[0])


def check_hopscotch_meta(ht):
    """Both directions of the bitmap invariant: bit d of meta[h] is set
    IFF cell (h+d)%m holds a key homed at h."""
    tab = np.asarray(ht.table)
    meta = np.asarray(ht.meta)
    m = tab.size
    Hn = min(H_NEIGHBORHOOD, m)
    for h in range(m):
        w = int(meta[h])
        assert w >> Hn == 0, f"meta[{h}] has bits beyond the neighborhood"
        for d in range(Hn):
            if (w >> d) & 1:
                j = (h + d) % m
                assert tab[j] != E.EMPTY, (h, d, "bit set on EMPTY cell")
                assert home_of(ht, int(tab[j]) >> 2) == h
    for j in range(m):
        if tab[j] == E.EMPTY:
            continue
        assert tab[j] != E.TOMBSTONE, "hopscotch table holds a TOMBSTONE"
        h = home_of(ht, int(tab[j]) >> 2)
        d = (j - h) % m
        assert d < Hn, (j, h, "resident outside its home neighborhood")
        assert (int(meta[h]) >> d) & 1, (j, h, "home bit missing")


# ---------------------------------------------------------------------------
# Contract conformance, parameterized over every strategy.


@pytest.mark.parametrize("strategy", ALL)
def test_roundtrip(strategy):
    impl = get_strategy(strategy)
    ht = BT.create(64, seed=1, strategy=strategy)
    keys = jnp.arange(10, dtype=jnp.uint32)
    ht, ret = impl.insert_batch(ht, keys)
    assert np.all(np.asarray(ret) == RET_TRUE)
    found, slots = impl.find_batch(ht, keys)
    assert np.all(np.asarray(found))
    assert np.all(np.asarray(slots) >= 0)
    miss, _ = impl.find_batch(ht, jnp.arange(100, 110, dtype=jnp.uint32))
    assert not np.any(np.asarray(miss))
    ht, ret = impl.delete_batch(ht, keys[:5])
    assert np.all(np.asarray(ret) == 1)
    present, _ = impl.find_batch(ht, keys)
    present = np.asarray(present)
    assert not np.any(present[:5]) and np.all(present[5:])
    assert int(ht.num_keys) == 5
    if impl.uses_tombstones:
        assert int(ht.num_tombs) == 5
    else:
        assert int(ht.num_tombs) == 0
        check_hopscotch_meta(ht)


@pytest.mark.parametrize("strategy", ALL)
def test_duplicate_inserts_one_winner(strategy):
    impl = get_strategy(strategy)
    ht = BT.create(16, strategy=strategy)
    keys = jnp.array([7, 7, 7, 7], dtype=jnp.uint32)
    ht, ret = impl.insert_batch(ht, keys)
    ret = np.asarray(ret)
    assert (ret == RET_TRUE).sum() == 1
    assert int(ht.num_keys) == 1
    assert ((np.asarray(ht.table) >> 2) == 7).sum() == 1


@pytest.mark.parametrize("strategy", ALL)
def test_apply_batch_matches_spec(strategy):
    """apply_batch == the documented serialization, for every strategy.
    m=16 <= H keeps the spec's ABORT condition exact for hopscotch too."""
    m = 16
    rng = np.random.default_rng(7)
    for seed in range(3):
        ht = BT.create(m, seed=seed, strategy=strategy)
        state = set()
        for _ in range(8):
            B = int(rng.integers(1, 24))
            ops = rng.integers(0, 3, size=B).astype(np.int32)
            keys = rng.integers(0, 10, size=B).astype(np.uint32)
            ht, ret = BT.apply_batch(ht, jnp.asarray(ops),
                                     jnp.asarray(keys), strategy=strategy)
            state, expect = spec_apply_grouped(state, list(ops),
                                               list(keys), m)
            assert list(np.asarray(ret)) == expect, (strategy, seed, ops,
                                                     keys)
        assert table_keys(ht) == state
        assert int(ht.num_keys) == len(state)


@pytest.mark.parametrize("strategy", ALL)
def test_linearizable_history(strategy):
    """Each batch application is one concurrent window (all lanes invoke at
    t, respond at t+1); the resulting history must be linearizable per the
    locality-theorem checker."""
    m = 16
    rng = np.random.default_rng(3)
    ht = BT.create(m, seed=2, strategy=strategy)
    rows = []
    for t in range(10):
        B = 8
        ops = rng.integers(0, 3, size=B).astype(np.int32)
        keys = rng.integers(0, 8, size=B).astype(np.uint32)
        ht, ret = BT.apply_batch(ht, jnp.asarray(ops), jnp.asarray(keys),
                                 strategy=strategy)
        ret = np.asarray(ret)
        for b in range(B):
            rows.append((b, t, int(ops[b]), int(keys[b]), int(ret[b]),
                         2 * t, 2 * t + 1))
    ok, bad = check_history(rows)
    assert ok, f"{strategy}: non-linearizable keys {bad}"


@pytest.mark.parametrize("strategy", ALL)
def test_counts_track_state(strategy):
    rng = np.random.default_rng(5)
    ht = BT.create(128, seed=2, strategy=strategy)
    for _ in range(8):
        ks = jnp.asarray(rng.integers(0, 60, size=32), jnp.uint32)
        ops = jnp.asarray(rng.integers(0, 3, size=32), jnp.int32)
        ht, _ = BT.apply_batch(ht, ops, ks, strategy=strategy)
    assert int(ht.num_keys) == len(table_keys(ht))
    tab = np.asarray(ht.table)
    assert int(ht.num_tombs) == int((tab == E.TOMBSTONE).sum())
    if strategy == "hopscotch":
        assert int(ht.num_tombs) == 0
        check_hopscotch_meta(ht)


def test_hopscotch_displacement_churn():
    """m > H forces the hop-displacement insert path: under heavy churn the
    table stays tombstone-free, counters exact, every live key findable,
    and the bitmap invariant holds in both directions."""
    impl = get_strategy("hopscotch")
    m = 64
    assert m > H_NEIGHBORHOOD
    rng = np.random.default_rng(11)
    ht = BT.create(m, seed=4, strategy="hopscotch")
    live = set()
    for _ in range(25):
        ks = rng.integers(0, 96, size=16).astype(np.uint32)
        ins = rng.random(16) < 0.6
        ins_keys = jnp.asarray(ks, jnp.uint32)
        ht, ret = impl.insert_batch(ht, ins_keys, active=jnp.asarray(ins))
        ret = np.asarray(ret)
        # ret == 1 marks the unique winning lane per key per batch
        for b in range(16):
            if ins[b] and ret[b] == 1:
                live.add(int(ks[b]))
        del_keys = rng.integers(0, 96, size=8).astype(np.uint32)
        ht, dret = impl.delete_batch(ht, jnp.asarray(del_keys))
        for b in range(8):
            if int(np.asarray(dret)[b]) == 1:
                live.discard(int(del_keys[b]))
        assert int(ht.num_tombs) == 0
    assert table_keys(ht) == live
    assert int(ht.num_keys) == len(live)
    found, _ = impl.find_batch(ht, jnp.asarray(sorted(live) or [0],
                                               jnp.uint32))
    if live:
        assert np.all(np.asarray(found))
    check_hopscotch_meta(ht)


# ---------------------------------------------------------------------------
# Bitwise parity: `linear` == the pre-refactor implementation.


def _load_parity_tool():
    path = os.path.join(os.path.dirname(__file__), os.pardir, "tools",
                        "record_probe_parity.py")
    spec = importlib.util.spec_from_file_location("record_probe_parity",
                                                  os.path.abspath(path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_linear_bitwise_parity_recorded_trace():
    """Replaying the recorded op trace must reproduce the digests captured
    BEFORE the ProbeStrategy refactor, step for step — the refactored
    linear path is bitwise-unchanged, not just observably equivalent."""
    tool = _load_parity_tool()
    fixture = os.path.join(os.path.dirname(__file__), "fixtures",
                           "probe_linear_parity.json")
    with open(fixture) as f:
        golden = json.load(f)
    records = tool.replay(BT, PT, jnp)
    assert len(records) == len(golden["records"]), "trace length changed"
    for got, want in zip(records, golden["records"]):
        assert got == want, f"parity break at step {want['step']}"


# ---------------------------------------------------------------------------
# Facade / headroom / kernel-gate surfaces.


@pytest.mark.parametrize("strategy", ALL)
def test_facade_alloc_free_cycle(strategy):
    """The page-table facade serves the allocator ops uniformly per
    strategy: alloc -> lookup -> free -> re-alloc reuses the pool."""
    pt = PT.for_strategy(strategy)
    B, psize, maxP = 4, 2, 4
    table = pt.create_table(32, seed=1)
    seq = jnp.arange(B, dtype=jnp.uint32)
    bt = jnp.full((B, maxP), -1, jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    for step in range(psize * maxP):
        st, bt = pt.alloc_step_incremental(table, seq, pos,
                                           bt, page_size=psize)
        table = st.table
        assert not np.any(np.asarray(st.aborted))
        assert np.all(np.asarray(st.write_slot) >= 0)
        pos = pos + 1
    rows = pt.lookup_pages(table, seq, pos, page_size=psize,
                           max_pages=maxP)
    assert np.all(np.asarray(rows) >= 0)
    assert int(pt.verify_block_table(table, seq, pos, bt,
                                     page_size=psize)) == 0
    table = pt.free_sequences(table, seq, pos, page_size=psize,
                              max_pages=maxP)
    hr = pt.headroom(table)
    assert hr.live_pages == 0 and hr.free_cells == hr.n_pages
    assert hr.strategy == strategy
    if strategy == "hopscotch":
        assert hr.tombstones == 0


def test_headroom_slack_per_strategy():
    assert PT.for_strategy("linear").forecast_slack(256) == 0
    assert PT.for_strategy("robinhood").forecast_slack(256) == 0
    hop = PT.for_strategy("hopscotch")
    # neighborhood covers the pool: near-claim sees every EMPTY cell,
    # the bound is exact, no slack
    assert hop.forecast_slack(H_NEIGHBORHOOD) == 0
    assert hop.forecast_slack(256) == H_NEIGHBORHOOD
    table = hop.create_table(256)
    assert hop.headroom(table).slack == H_NEIGHBORHOOD


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError, match="unknown probe strategy"):
        get_strategy("quadratic")
    with pytest.raises(ValueError, match="unknown probe strategy"):
        PT.PageTable("quadratic")


def test_probe_kernel_guard():
    """The Pallas probe kernel serves exactly the linear-order strategies;
    bypassing the facade with hopscotch raises instead of returning
    linear-scan garbage."""
    from repro.kernels.probe import ops as PK
    ht = BT.create(64, strategy="linear")
    keys = jnp.arange(4, dtype=jnp.uint32)
    # robinhood lookups are bitwise the linear scan — accepted
    found, _ = PK.probe_lookup(ht, keys, use_kernel=False,
                               strategy="robinhood")
    assert not np.any(np.asarray(found))
    with pytest.raises(ValueError, match="linear order"):
        PK.probe_lookup(ht, keys, use_kernel=False, strategy="hopscotch")


def test_module_aliases_removed():
    """The deprecated PT.* module-function aliases (PR 7's one-PR window)
    are gone: the strategy-bound facade is the only page-table API, so no
    call site can silently bake in the linear strategy again."""
    for name in ("create_table", "alloc_step", "alloc_step_incremental",
                 "prefill_alloc", "free_sequences", "lookup_pages",
                 "rebuild_block_table", "rehash", "headroom"):
        assert not hasattr(PT, name), f"PT.{name} alias resurfaced"
    # ...and the facade serves the same calls
    pt = PT.for_strategy("linear")
    table = pt.create_table(16, seed=0)
    seq = jnp.arange(2, dtype=jnp.uint32)
    pos = jnp.zeros((2,), jnp.int32)
    st = pt.alloc_step(table, seq, pos, page_size=4)
    assert not np.any(np.asarray(st.aborted))
    assert pt.headroom(st.table).strategy == "linear"
