"""Model-substrate tests: flash attention (fwd + custom VJP) and Mamba2 SSD
against naive oracles, incl. hypothesis sweeps over shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import flash_attention
from repro.models.ssm import ssd_chunked


def naive_attn(q, k, v, causal=True, window=0):
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) / np.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


@settings(max_examples=12, deadline=None)
@given(
    S=st.integers(8, 96),
    Hkv=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2]),
    causal=st.booleans(),
    window=st.sampled_from([0, 8, 24]),
    qc=st.sampled_from([8, 16, 32]),
    kc=st.sampled_from([8, 16, 32]),
)
def test_flash_attention_matches_naive(S, Hkv, G, causal, window, qc, kc):
    if window and not causal:
        window = 0
    H = Hkv * G
    key = jax.random.PRNGKey(S * 131 + H)
    q = jax.random.normal(key, (2, S, H, 8), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, S, Hkv, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, S, Hkv, 8))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=kc)
    ref = naive_attn(q, k, v, causal, window)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                           (False, 0)])
def test_flash_attention_vjp(causal, window):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 64, 4, 8), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 8))

    def f(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, causal=causal, window=window, q_chunk=16, kv_chunk=16)))

    def g(q, k, v):
        return jnp.sum(jnp.sin(naive_attn(q, k, v, causal, window)))

    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gg = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gg):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-4)


def naive_ssd(x, dt, A, Bm, Cm, D):
    B, S, G, Hg, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, G, Hg, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None])
        xdt = x[:, t] * dt[:, t][..., None]
        h = h * dA[..., None, None] + jnp.einsum("bgn,bghp->bghpn",
                                                 Bm[:, t], xdt)
        y = jnp.einsum("bgn,bghpn->bghp", Cm[:, t], h) \
            + x[:, t] * D[None, ..., None]
        ys.append(y)
    return jnp.stack(ys, axis=1), h


@settings(max_examples=10, deadline=None)
@given(
    S=st.sampled_from([8, 16, 32, 64]),
    chunk=st.sampled_from([4, 8, 16]),
    Hg=st.integers(1, 4),
    N=st.sampled_from([2, 4, 8]),
)
def test_ssd_matches_recurrence(S, chunk, Hg, N):
    if S % chunk:
        chunk = S
    key = jax.random.PRNGKey(S + 7 * Hg)
    B, G, P = 2, 1, 4
    x = jax.random.normal(key, (B, S, G, Hg, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, G, Hg)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2),
                                   (G, Hg)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N))
    D = jnp.ones((G, Hg))
    y1, h1 = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    y2, h2 = naive_ssd(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(h1, h2, atol=2e-4, rtol=1e-3)


def test_ssd_gradients_finite():
    key = jax.random.PRNGKey(3)
    B, S, G, Hg, P, N = 1, 16, 1, 2, 4, 4
    x = jax.random.normal(key, (B, S, G, Hg, P))
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, G, Hg)))
    A = -jnp.exp(jnp.zeros((G, Hg)))
    Bm = jax.random.normal(key, (B, S, G, N))
    Cm = jax.random.normal(key, (B, S, G, N))
    D = jnp.ones((G, Hg))

    def f(x, Bm, Cm):
        y, _ = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)
        return jnp.sum(y ** 2)

    grads = jax.grad(f, argnums=(0, 1, 2))(x, Bm, Cm)
    for g in grads:
        assert jnp.isfinite(g).all()
