"""Minimal deterministic stand-in for ``hypothesis`` (property testing).

The real library is a test extra (see pyproject.toml) and is installed in
CI; on boxes without it, this shim registers a ``hypothesis`` module
providing the tiny API surface the suite uses — ``given``, ``settings`` and
the ``integers / sampled_from / booleans / lists / tuples`` strategies — and
runs each property with a deterministic per-test sample sweep (seeded by the
test name, so failures reproduce).  No shrinking, no database; just honest
randomized coverage so missing deps can never silently skip the suite.

Imported for its side effect from ``conftest.py`` BEFORE test modules load.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size=None) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 16

    def draw(rng):
        n = rng.randint(min_size, hi)
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))


_DEFAULT_MAX_EXAMPLES = 10


def given(*pos_strategies, **kw_strategies):
    def decorate(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # real hypothesis fills positional strategies from the RIGHT
        # (leftmost params stay free for fixtures); match that
        strategies = dict(zip(names[len(names) - len(pos_strategies):],
                              pos_strategies))
        strategies.update(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(seed + i)
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property failed (fallback-hypothesis example "
                        f"{i}/{n}): {drawn!r}") from e

        # hide the property parameters from pytest's fixture resolution
        # (the real hypothesis does the same)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(
            [p for n, p in sig.parameters.items() if n not in strategies])
        wrapper._hyp_strategies = strategies
        return wrapper

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return decorate


def install() -> None:
    """Register this shim as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__version__ = "0.0.0-fallback"
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.booleans = booleans
    st.lists = lists
    st.tuples = tuples
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
