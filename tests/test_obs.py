"""Telemetry-plane tests (obs/): the identity fast path, golden trace
determinism, the metrics registry exporters, and the trace-invariant
checker that CI runs over soak traces.

The two load-bearing pins:

* ``test_telemetry_off_parity`` — with ``cfg.telemetry`` off the decode
  state has no ``counters`` leaf and the megastep's tokens AND every state
  leaf are bitwise identical to the instrumented run's (the counter plane
  may not change a single bit of the decode, on or off); and
* ``test_trace_determinism`` — the same storm traced twice produces
  byte-identical JSONL (virtual clock only, sorted keys, fixed
  separators), which is what makes traces diffable across CI runs.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import json
import pathlib

import numpy as np
import pytest

import jax

import _multihost as MH
from repro import obs as OBS
from repro.configs import get_smoke_config
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving.sched import synthetic_workload


def _load_trace_report():
    p = (pathlib.Path(__file__).resolve().parent.parent
         / "tools" / "trace_report.py")
    spec = importlib.util.spec_from_file_location("trace_report", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# counter plane
# ---------------------------------------------------------------------------

def test_telemetry_off_parity():
    """cfg.telemetry=False is an identity: no counters leaf, and the
    megastep's tokens and every shared state leaf match the telemetry=True
    run bitwise (the plane is pure observation)."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, K = 2, 8
    tok0 = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                              cfg.vocab_size)

    s_off, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4)
    assert "counters" not in s_off
    mega_off = jax.jit(EG.make_serve_megastep(cfg, S_max=32, K=K,
                                              page_size=4))
    t_off, st_off = mega_off(params, s_off, tok0)

    cfg_on = dataclasses.replace(cfg, telemetry=True)
    s_on, _ = EG.make_decode_state(cfg_on, B, S_max=32, page_size=4)
    assert "counters" in s_on
    mega_on = jax.jit(EG.make_serve_megastep(cfg_on, S_max=32, K=K,
                                             page_size=4))
    t_on, st_on = mega_on(params, s_on, tok0)

    np.testing.assert_array_equal(np.asarray(t_off), np.asarray(t_on))
    for k in st_off:
        same = jax.tree.map(
            lambda x, y: bool(np.array_equal(np.asarray(x), np.asarray(y))),
            st_off[k], st_on[k])
        assert all(jax.tree.leaves(same)), f"leaf {k} diverged"

    c = OBS.snapshot(st_on["counters"])
    assert c["tokens_accepted"] == B * K
    assert c["pages_allocated"] > 0
    # probe twin of alloc_step_incremental's 2*need_new host note
    assert c["probe_steps"] == 2 * c["pages_allocated"]
    assert c["abort_events"] == 0


def test_host_counters_scope_is_additive():
    OBS.note_host("migration_moved", 3)
    with OBS.host_counters_scope() as h:
        assert h["migration_moved"] == 0
        OBS.note_host("migration_moved", 2)
        assert h["migration_moved"] == 2
    assert OBS.HOST_COUNTERS["migration_moved"] >= 5  # outer + body


# ---------------------------------------------------------------------------
# trace determinism
# ---------------------------------------------------------------------------

def _run_traced_storm(path):
    wl = synthetic_workload(2, vocab_size=256, max_len=16, seed=0,
                            prompt_len=(2, 4), max_new=(8, 10))
    with OBS.Tracer(str(path)) as tr:
        cluster = MH.SimCluster(hosts=2, pages_per_shard=16,
                                slots_per_shard=2, page_size=4,
                                max_len=16, megastep_k=4, tracer=tr)
        s = cluster.run_storm(wl, max_rounds=60, grow_round=1)
    assert int(s["completed"]) == 2
    return path.read_bytes()


def test_trace_determinism(tmp_path):
    """Two identical 2-request churn storms -> byte-identical traces, and
    the trace passes the CI invariant checker."""
    a = _run_traced_storm(tmp_path / "a.jsonl")
    b = _run_traced_storm(tmp_path / "b.jsonl")
    assert a == b, "trace is not deterministic across identical runs"

    tr = _load_trace_report()
    evs = tr.load(str(tmp_path / "a.jsonl"))
    assert tr.check_invariants(str(tmp_path / "a.jsonl"), evs) == []
    kinds = {e["event"] for e in evs}
    # the storm grew a shard, so the window events must be in the stream
    assert {"arrival", "admit", "decode", "shard_health", "grow",
            "migrate", "summary"} <= kinds
    assert evs[-1]["event"] == "summary"


def test_trace_invariant_checker_catches_violations(tmp_path):
    tr = _load_trace_report()
    lines = [
        '{"clock":0,"event":"arrival","req":1}',
        # decode before admit -> lifecycle violation
        '{"clock":1,"event":"decode","pages":1,"reqs":[1],"shard":0,'
        '"tokens":4}',
        '{"clock":1,"event":"grow","n_pages_new":16,"n_pages_old":8,'
        '"shard":0}',
        '{"clock":2,"event":"admit","prefill":2,"req":1,"slot":0}',
        # window open, pages>0, no migrate at clock 3 -> window violation
        '{"clock":3,"event":"decode","pages":2,"reqs":[1],"shard":0,'
        '"tokens":4}',
        '{"clock":4,"event":"abort","grew_to":null,"lanes":2}',
        '{"clock":5,"event":"finish","req":1,"tokens":4,"tpot":1.0,'
        '"ttft":3}',
        # 2 abort lanes vs aborts=1 -> reconciliation violation
        '{"clock":5,"event":"summary","aborts":1,"completed":1}',
    ]
    p = tmp_path / "bad.jsonl"
    p.write_text("\n".join(lines) + "\n")
    bad = tr.check_invariants(str(p), tr.load(str(p)))
    assert len(bad) == 3, bad
    assert any("outside an admitted interval" in b for b in bad)
    assert any("frozen-old-table window" in b for b in bad)
    assert any("summary reports aborts=1" in b for b in bad)


# ---------------------------------------------------------------------------
# registry + exporters
# ---------------------------------------------------------------------------

def test_metrics_registry_exporters():
    reg = OBS.MetricsRegistry(namespace="t")
    reg.inc("probe_steps", 5)
    reg.inc("probe_steps", 2)
    reg.set_gauge("occupancy", 0.5)
    reg.source("fb", lambda: {"a": 1, "mode": "ok"})
    snap = reg.snapshot()
    assert snap["counters"]["probe_steps"] == 7
    assert snap["gauges"]["fb_a"] == 1
    assert snap["info"]["fb_mode"] == "ok"

    text = reg.prometheus_text()
    assert "# TYPE t_probe_steps counter" in text
    assert "t_probe_steps 7" in text
    assert "t_occupancy 0.5" in text
    assert 't_info{key="fb_mode",value="ok"} 1' in text

    loaded = json.loads(reg.json_snapshot())
    assert loaded["counters"]["probe_steps"] == 7

    # a dying source degrades to an info entry instead of killing serving
    reg.source("dead", lambda: (_ for _ in ()).throw(RuntimeError("x")))
    snap2 = reg.snapshot()
    assert "dead_error" in snap2["info"]
