"""Pytest hygiene: drop JAX's compiled-executable caches between test
modules.  The suite compiles hundreds of programs (ten architectures x
train/decode engines x schedulers); on a CPU host the accumulated LLVM
executables otherwise exhaust memory late in the run ("LLVM compilation
error: Cannot allocate memory").  Per the dry-run isolation rule, this file
must NOT set XLA_FLAGS / device counts."""
import gc

try:                                     # real hypothesis when installed
    import hypothesis  # noqa: F401
except ImportError:                      # deterministic fallback (no pip)
    import _hypothesis_fallback
    _hypothesis_fallback.install()

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
    gc.collect()
