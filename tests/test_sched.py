"""Scheduler tests: forecaster page math, policies, the PROBE_STATS scoped
lifecycle, idempotent double-evict, deadline-miss accounting, chunked
prefill through the megastep, and the adversarial admission storm where the
forecaster-driven scheduler provably avoids ABORT (0 aborts with the
headroom controller on, >= 1 with it off, same request set completed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.serve import ContinuousBatcher
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT

LPT = PT.for_strategy("linear")  # the strategy-bound facade
from repro.serving.sched import (DeadlinePolicy, OccupancyForecaster,
                                 PriorityPolicy, Request, Scheduler,
                                 get_policy, pages_held, pages_needed,
                                 synthetic_workload)


# ---------------------------------------------------------------------------
# Forecaster: exact page math.

def test_pages_math_exact():
    """pages_needed counts exactly the page-boundary crossings the engine's
    alloc_step performs over [pos, pos+steps) — brute-force checked."""
    for ps in (2, 4, 8):
        for pos in range(0, 20):
            assert pages_held(pos, ps) == -(-pos // ps)
            for steps in range(0, 20):
                brute = sum(1 for q in range(pos, pos + steps)
                            if q % ps == 0)
                assert pages_needed(pos, steps, ps) == brute, (ps, pos,
                                                               steps)


def test_forecaster_exhaustion_boundary():
    """The hard invariant flags exhaustion exactly when demand exceeds the
    free cells: one page short -> exhausted, exact fit -> not."""
    fc = OccupancyForecaster(page_size=4)
    # 3 lanes at positions 0,4,6 with long stops, horizon 4 steps:
    # crossings = 1 (at 0) + 1 (at 4) + 1 (at 8) = 3 pages
    pos, stop = [0, 4, 6], [100, 100, 100]
    f = fc.forecast(pos, stop, free_cells=3, horizon_steps=4)
    assert f.demand_pages == 3 and not f.exhausted and f.margin == 0
    f = fc.forecast(pos, stop, free_cells=2, horizon_steps=4)
    assert f.exhausted and f.margin == -1
    # a lane about to stop contributes only its remaining steps
    f = fc.forecast([0], [2], free_cells=0, horizon_steps=8)
    assert f.demand_pages == 1 and f.exhausted
    f = fc.forecast([2], [2], free_cells=0, horizon_steps=8)
    assert f.demand_pages == 0 and not f.exhausted


def test_forecaster_trends():
    fc = OccupancyForecaster(page_size=4, ewma=1.0)
    fc.observe(admitted=4, live_pages=8, steps=4)
    fc.observe(admitted=0, live_pages=16, steps=4)
    assert fc.admit_rate == 0.0             # ewma=1.0 -> last sample
    assert fc.growth_slope == pytest.approx(2.0)
    f = fc.forecast([0], [100], free_cells=20, horizon_steps=4)
    assert np.isfinite(f.est_steps_to_exhaustion)
    assert f.est_steps_to_exhaustion == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# Policies.

def _req(i, *, prio=0, slo=None, arrival=0, state="queued", slot=None,
         admitted=None):
    r = Request(req_id=i, prompt=np.zeros(1, np.int32), max_new_tokens=4,
                priority=prio, max_latency=slo, arrival=arrival)
    r.state, r.slot, r.admitted_at = state, slot, admitted
    return r


def test_policy_orders():
    q = [_req(0, arrival=5), _req(1, arrival=1),
         _req(2, arrival=1, prio=9, slo=10), _req(3, arrival=3, slo=2)]
    assert [r.req_id for r in get_policy("fcfs").admit_order(q)] \
        == [1, 2, 3, 0]
    assert [r.req_id for r in PriorityPolicy().admit_order(q)] \
        == [2, 1, 3, 0]
    # EDF: deadlines 11 (req 2), 5 (req 3), none (0, 1) -> 3, 2, then FCFS
    assert [r.req_id for r in DeadlinePolicy().admit_order(q)] \
        == [3, 2, 1, 0]


def test_policy_preempt_candidates():
    running = [_req(0, prio=0, state="running", slot=0, admitted=0),
               _req(1, prio=2, state="running", slot=1, admitted=4),
               _req(2, prio=5, state="running", slot=2, admitted=2)]
    queue_hi = [_req(9, prio=3)]
    # FCFS never preempts (grow instead)
    assert get_policy("fcfs").preempt_candidates(running, queue_hi) == []
    # priority: only lanes strictly below the best queued priority,
    # lowest first
    vict = PriorityPolicy().preempt_candidates(running, queue_hi)
    assert [r.req_id for r in vict] == [0, 1]
    assert PriorityPolicy().preempt_candidates(running, []) == []
    # deadline: lanes with more slack than the most urgent queued SLO;
    # no-SLO lanes yield first
    run2 = [_req(0, slo=100, state="running", slot=0),
            _req(1, slo=3, state="running", slot=1),
            _req(2, state="running", slot=2)]
    vict = DeadlinePolicy().preempt_candidates(run2, [_req(9, slo=5)])
    assert [r.req_id for r in vict] == [2, 0]
    assert DeadlinePolicy().preempt_candidates(run2, [_req(9)]) == []


# ---------------------------------------------------------------------------
# PROBE_STATS lifecycle (the counter-bleed fix).

def test_probe_stats_scope_isolates():
    PT.probe_stats_reset()
    table = LPT.create_table(32)
    seq = jnp.arange(2, dtype=jnp.int32)
    LPT.lookup_pages(table, seq, jnp.zeros(2, jnp.int32), page_size=4,
                    max_pages=4)
    outer = PT.PROBE_STATS["keys_probed"]
    assert outer > 0
    with PT.probe_stats_scope() as ps:
        assert ps["keys_probed"] == 0        # scope starts clean
        LPT.lookup_pages(table, seq, jnp.zeros(2, jnp.int32), page_size=4,
                        max_pages=4)
        inner = ps["keys_probed"]
        assert inner == outer                # same op, same count
        with PT.probe_stats_scope() as ps2:  # scopes nest
            assert ps2["keys_probed"] == 0
        assert ps["keys_probed"] == inner    # inner scope didn't leak
    # the enclosing counter is RESTORED: no cross-run bleed
    assert PT.PROBE_STATS["keys_probed"] == outer
    PT.probe_stats_reset()
    assert PT.PROBE_STATS["keys_probed"] == 0


# ---------------------------------------------------------------------------
# Scheduler unit behavior (engine-free: simulated lane positions).

def _drive(sched, n_rounds, pool_pages=None):
    """Simulate the driver: each round every occupied lane advances K steps
    (clamped at its stop); a fake Headroom tracks exact page usage."""
    B, K, ps = sched.B, sched.K, sched.page_size
    pos = np.zeros(B, np.int64)
    for _ in range(n_rounds):
        for s, r in enumerate(sched.lanes):
            if r is not None:
                pos[s] = min(pos[s] + K, sched.stop_of(r))
        sched.advance(K)
        pool = None
        if pool_pages is not None:
            live = sum(pages_held(pos[s], ps)
                       for s, r in enumerate(sched.lanes) if r is not None)
            pool = PT.Headroom(n_pages=pool_pages, live_pages=live,
                               tombstones=0, free_cells=pool_pages - live,
                               live_fraction=live / pool_pages,
                               occupancy=live / pool_pages)
        plan = sched.plan_round(pos, pool)
        for s in plan.evict_slots:
            pos[s] = 0
        for s, _ in plan.admissions:
            pos[s] = 0
        if plan.grow_to is not None:
            pool_pages = plan.grow_to
        sched.end_round()
    return pos, pool_pages


def test_double_evict_idempotent():
    """Evicting the same request twice is a no-op the second time, at both
    layers: the scheduler's state machine refuses it, and a double
    free_sequences on the table leaves the counters unchanged."""
    sched = Scheduler(slots=2, page_size=4, max_len=16, megastep_k=4)
    a, b = _req(0), _req(1)
    sched.submit(a)
    sched.submit(b)
    _drive(sched, 1)
    assert a.state == "running" and b.state == "running"
    assert sched.evict(a) is True
    base = sched.stats.preemptive_evictions
    assert sched.evict(a) is False           # idempotent double-evict
    assert sched.stats.preemptive_evictions == base
    assert sched.lanes[0] is None and a in sched.queue
    assert sched.queue.count(a) == 1         # not double-queued
    # finished request can't be evicted either
    sched._finish(b)
    assert sched._finish(b) is False and sched.stats.completed == 1
    assert sched.evict(b) is False

    # table layer: double free of the same sequence is a no-op
    table = LPT.create_table(16)
    seq = jnp.arange(2, dtype=jnp.int32)
    for p in range(8):
        table, ws, ab = LPT.alloc_step(table, seq,
                                      jnp.full((2,), p, jnp.int32),
                                      page_size=4)
    mask = jnp.asarray([True, False])
    table = LPT.free_sequences(table, seq, jnp.full((2,), 8, jnp.int32),
                              page_size=4, max_pages=4, active=mask)
    k1, t1 = int(table.num_keys), int(table.num_tombs)
    table = LPT.free_sequences(table, seq, jnp.full((2,), 8, jnp.int32),
                              page_size=4, max_pages=4, active=mask)
    assert (int(table.num_keys), int(table.num_tombs)) == (k1, t1)


def test_deadline_miss_accounting():
    """Requests whose SLO cannot be met (queue too deep) are counted as
    deadline misses exactly once, at completion; generous SLOs are not."""
    sched = Scheduler(slots=1, page_size=4, max_len=16, megastep_k=4,
                      policy="deadline")
    # 3 requests, 1 slot, each needs ~12 steps: the third cannot make a
    # 20-step SLO; a 500-step SLO is safe
    for i, slo in enumerate((20, 20, 500)):
        sched.submit(Request(req_id=i, prompt=np.zeros(1, np.int32),
                             max_new_tokens=11, max_latency=slo))
    _drive(sched, 12)
    assert sched.drained
    assert sched.stats.completed == 3
    assert sched.stats.deadline_misses == 1
    missed = [r for r in sched.finished if r.missed_deadline]
    assert [r.req_id for r in missed] == [1]  # EDF served 0 first
    # accounting is per-request-completion, never double counted
    assert sum(bool(r.missed_deadline) for r in sched.finished) == 1


def test_admission_gate_defers_under_pressure():
    """Proactive admission control: with a pool that can only sustain two
    lanes over the horizon, the third request WAITS even though a slot is
    free — and is admitted once capacity drains."""
    sched = Scheduler(slots=3, page_size=4, max_len=16, megastep_k=4,
                      horizon_rounds=2)
    for i in range(3):
        sched.submit(Request(req_id=i, prompt=np.zeros(1, np.int32),
                             max_new_tokens=11))
    # pool of 4 pages: two 12-step lanes demand 2*2=4 pages over H=8
    _drive(sched, 1, pool_pages=4)
    assert sum(r is not None for r in sched.lanes) == 2
    assert len(sched.queue) == 1             # deferred, not rejected
    _drive(sched, 10, pool_pages=4)
    assert sched.drained and sched.stats.completed == 3
    assert sched.stats.aborts == 0


def test_grow_cap_bounds_the_result():
    """``max_pool_pages`` bounds the grown pool itself — a doubling that
    would overshoot the cap is refused (the controller then preempts or
    falls through to the reactive path), never applied at 2x the cap."""
    sched = Scheduler(slots=4, page_size=2, max_len=64, megastep_k=4,
                      max_pool_pages=24)
    sched.n_pages = 16
    for i in range(4):
        sched.submit(Request(req_id=i, prompt=np.zeros(1, np.int32),
                             max_new_tokens=60))
    _drive(sched, 12, pool_pages=16)
    grew = [rs.grew_to for rs in sched.rounds if rs.grew_to is not None]
    assert sched.stats.pool_grows >= 1, "cap test never grew"
    assert all(g <= 24 for g in grew), grew
    assert sched.n_pages <= 24


def test_trend_gate_defers_admissions_on_growth():
    """The EWMA trend term is consulted, not just computed: with a steep
    observed live-page slope, ``est_steps_to_exhaustion`` falls inside the
    lookahead and new admissions are deferred even though a slot is free
    and the exact-demand margin would fit."""
    sched = Scheduler(slots=4, page_size=1, max_len=64, megastep_k=4,
                      horizon_rounds=2)
    # hand-feed the forecaster a steep slope: 4 pages/step
    sched.forecaster.observe(admitted=0, live_pages=0, steps=4)
    sched.forecaster.observe(admitted=0, live_pages=32, steps=4)
    assert sched.forecaster.growth_slope > 0
    sched.submit(Request(req_id=0, prompt=np.zeros(1, np.int32),
                         max_new_tokens=4))
    pool = PT.Headroom(n_pages=40, live_pages=24, tombstones=0,
                       free_cells=16, live_fraction=0.6, occupancy=0.6)
    sched.advance(4)
    plan = sched.plan_round(np.zeros(4, np.int64), pool)
    sched.end_round()
    # est = 16 / slope(~2-4 ewma'd) < horizon 8 -> deferred
    assert plan.admissions == [] and len(sched.queue) == 1


def test_readmission_resets_recurrent_state():
    """A request seated into a reused slot must decode from the same zero
    recurrent state a fresh batcher would give it: the previous occupant's
    mamba recurrence (h / conv tails) and ring-buffer history may not leak
    into the re-seated lane.  Pinned by comparing the follow-up request's
    sampled tokens in a churned single-slot batcher against the same
    request alone in a fresh batcher."""
    for arch in ("zamba2-1.2b", "gemma3-12b"):
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params, _ = model.init(cfg, jax.random.PRNGKey(0))
        pc = np.asarray(
            jax.random.randint(jax.random.PRNGKey(2), (3,), 0,
                               cfg.vocab_size), np.int32)

        def run(workload):
            sched = Scheduler(slots=1, page_size=4, max_len=24,
                              megastep_k=4)
            srv = ContinuousBatcher(cfg, params, batch=1, max_len=24,
                                    page_size=4, megastep_k=4,
                                    scheduler=sched, auto_refill=False)
            sched.submit_many(workload)
            assert srv.run_until_drained(max_rounds=200)
            return {r.req_id: r.sampled for r in sched.finished}

        alone = run([Request(req_id=9, prompt=pc, max_new_tokens=8)])
        churned = run([
            Request(req_id=0, prompt=np.full(2, 5, np.int32),
                    max_new_tokens=10),
            Request(req_id=9, prompt=pc, max_new_tokens=8)])
        assert churned[9] == alone[9], (
            f"{arch}: stale recurrent state leaked into the reused slot")


# ---------------------------------------------------------------------------
# Chunked prefill through the megastep (engine-level).

def test_chunked_prefill_matches_teacher_forcing():
    """The megastep's forced-token path IS teacher forcing: a prompt fed
    via forced/forced_mask produces bitwise the same tokens and state as a
    single-step driver that feeds prompt tokens explicitly, including the
    mid-megastep flip from prefill to greedy decode."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, K, Lp = 2, 6, 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, Lp), 0,
                                cfg.vocab_size)
    step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4))
    state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4)

    st, tok = dict(state), prompt[:, 0:1]
    ref = []
    for t in range(K):
        lg, st = step(params, st, tok, st["pos"])
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        tok = prompt[:, t + 1:t + 2] if t + 1 < Lp else nxt
        ref.append(np.asarray(tok[:, 0]))
    ref = np.stack(ref, axis=1)

    mega = jax.jit(EG.make_serve_megastep(cfg, S_max=32, K=K, page_size=4))
    forced = np.zeros((B, K), np.int32)
    fmask = np.zeros((B, K), bool)
    for k in range(K):
        if k + 1 < Lp:
            forced[:, k] = np.asarray(prompt[:, k + 1])
            fmask[:, k] = True
    state2, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4)
    toks, mst = mega(params, state2, prompt[:, 0:1],
                     jnp.full((B,), 30, jnp.int32), jnp.asarray(forced),
                     jnp.asarray(fmask))
    np.testing.assert_array_equal(np.asarray(toks), ref)
    for k in st:
        same = all(jax.tree.leaves(jax.tree.map(
            lambda x, y: bool(np.array_equal(np.asarray(x),
                                             np.asarray(y))),
            st[k], mst[k])))
        assert same, f"state leaf {k} diverged"


# ---------------------------------------------------------------------------
# The adversarial admission storm (the PR's acceptance criterion).

def test_admission_storm_forecaster_avoids_abort():
    """admit-rate >> drain-rate churn on a 2x-overcommitted pool: with the
    occupancy forecaster ON the scheduler completes the whole request set
    with ZERO allocator ABORTs (proactive grow/evict strictly before
    exhaustion — the wait-free lookup path never sees a mid-flight
    rebuild); the reactive baseline (forecaster off) hits the ABORT ->
    §4.3-rebuild path at least once on the identical workload.  Both runs
    complete every request with its full token budget."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))

    def run(proactive):
        sched = Scheduler(slots=4, page_size=4, max_len=32, megastep_k=4,
                          policy="fcfs", proactive=proactive)
        srv = ContinuousBatcher(cfg, params, batch=4, max_len=32,
                                page_size=4, megastep_k=4,
                                verify_block_table=True, scheduler=sched,
                                n_pages=16,     # 2x overcommitted (maxP=8)
                                auto_refill=False)
        sched.submit_many(synthetic_workload(
            10, vocab_size=cfg.vocab_size, max_len=32, seed=0,
            prompt_len=(2, 5), max_new=(18, 26)))
        assert srv.run_until_drained(max_rounds=300)
        for r in sched.finished:     # full budget generated, storm or not
            assert len(r.sampled) == min(r.total_len, 32) - r.prompt.size
        return sched

    on = run(True)
    off = run(False)
    assert on.stats.completed == off.stats.completed == 10
    assert on.stats.aborts == 0, "forecaster-on run ABORTed"
    assert off.stats.aborts >= 1, "reactive baseline never aborted " \
        "(the adversarial workload is no longer adversarial)"
    assert on.stats.aborts_avoided >= 1
    assert on.stats.pool_grows + on.stats.preemptive_evictions >= 1
    # per-round stats surface the scoped probe counter and the occupancy
    assert any(rs.keys_probed > 0 for rs in on.rounds)
    assert all(rs.free_cells is not None for rs in on.rounds)
    # latency accounting exists and is deterministic
    lat = on.latency_summary()
    assert np.isfinite(lat["ttft_p50"]) and lat["ttft_p50"] >= 0


def test_priority_storm_preempts_low_priority():
    """SLO/priority pressure with growth DISABLED: when high-priority work
    arrives against a full overcommitted pool, the headroom controller
    preemptively evicts low-priority lanes (recompute preemption) instead
    of aborting; victims re-queue, re-admit, and still complete with their
    full token budget."""
    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    sched = Scheduler(slots=4, page_size=4, max_len=32, megastep_k=4,
                      policy="priority", proactive=True, allow_grow=False)
    wl = [Request(req_id=i, prompt=np.full(2, 7, np.int32),
                  max_new_tokens=26, priority=0) for i in range(4)]
    wl += [Request(req_id=10 + i, prompt=np.full(2, 9, np.int32),
                   max_new_tokens=10, priority=5, arrival=8)
           for i in range(4)]
    srv = ContinuousBatcher(cfg, params, batch=4, max_len=32, page_size=4,
                            megastep_k=4, verify_block_table=True,
                            scheduler=sched, n_pages=20, auto_refill=False)
    sched.submit_many(wl)
    assert srv.run_until_drained(max_rounds=300)
    s = sched.stats
    assert s.completed == 8 and s.aborts == 0 and s.pool_grows == 0
    assert s.preemptive_evictions >= 1
    preempted = [r for r in sched.finished if r.preemptions > 0]
    assert preempted and all(r.priority == 0 for r in preempted)
    for r in sched.finished:
        assert len(r.sampled) == min(r.total_len, 32) - r.prompt.size
