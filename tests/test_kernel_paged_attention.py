"""Paged-attention kernel vs oracle (interpret mode), shape/dtype sweeps,
and TP head-shard slicing (the fused manual decode layout)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref, shard_heads)


def make_case(rng, B, QH, KH, D, NP, PS, MP, dtype):
    q = jnp.asarray(rng.standard_normal((B, QH, D)), dtype)
    k = jnp.asarray(rng.standard_normal((NP, PS, KH, D)), dtype)
    v = jnp.asarray(rng.standard_normal((NP, PS, KH, D)), dtype)
    lens = jnp.asarray(rng.integers(1, MP * PS + 1, size=B), jnp.int32)
    # each sequence gets distinct physical pages for its used range
    ids = np.full((B, MP), -1, np.int32)
    perm = rng.permutation(NP)
    c = 0
    for b in range(B):
        used = -(-int(lens[b]) // PS)
        ids[b, :used] = perm[c:c + used]
        c += used
    return q, k, v, jnp.asarray(ids), lens


@pytest.mark.parametrize("B,QH,KH,D,NP,PS,MP", [
    (2, 4, 4, 32, 16, 8, 4),     # MHA
    (2, 8, 2, 32, 16, 8, 4),     # GQA G=4
    (1, 4, 1, 16, 32, 16, 8),    # MQA, longer
    (3, 6, 2, 64, 24, 8, 4),     # G=3, D=64
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(B, QH, KH, D, NP, PS, MP, dtype):
    rng = np.random.default_rng(B * 100 + QH)
    q, k, v, ids, lens = make_case(rng, B, QH, KH, D, NP, PS, MP, dtype)
    out_ref = paged_attention_ref(q, k, v, ids, lens)
    out_k = paged_attention(q, k, v, ids, lens, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out_k, jnp.float32),
                               np.asarray(out_ref, jnp.float32),
                               rtol=tol, atol=tol)


def test_single_token_and_page_boundary():
    rng = np.random.default_rng(0)
    B, QH, KH, D, NP, PS, MP = 2, 2, 2, 16, 8, 4, 3
    q, k, v, ids, _ = make_case(rng, B, QH, KH, D, NP, PS, MP, jnp.float32)
    for L in [1, PS, PS + 1, MP * PS]:
        lens = jnp.full((B,), L, jnp.int32)
        out_ref = paged_attention_ref(q, k, v, ids, lens)
        out_k = paged_attention(q, k, v, ids, lens, interpret=True)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_head_shard_slices_match_full(n_shards):
    """Per-TP-shard kernel launches over head slices concatenate to the full
    kernel output exactly — the invariant the fused manual decode region
    relies on (heads never cross chips, no cross-shard combine needed)."""
    rng = np.random.default_rng(7)
    B, QH, KH, D, NP, PS, MP = 2, 8, 4, 32, 16, 8, 4
    q, k, v, ids, lens = make_case(rng, B, QH, KH, D, NP, PS, MP,
                                   jnp.float32)
    full = np.asarray(paged_attention(q, k, v, ids, lens, interpret=True))
    parts = []
    for s in range(n_shards):
        qs, ks, vs = shard_heads(q, k, v, s, n_shards)
        parts.append(np.asarray(
            paged_attention(qs, ks, vs, ids, lens, interpret=True)))
    np.testing.assert_array_equal(np.concatenate(parts, axis=1), full)


def test_head_shard_replicated_kv_slices_match_full():
    """KV replication (n_shards wider than KH, kv_rep = n_shards/KH): each
    shard keeps ONE replicated KV head and a disjoint q-head slice of that
    head's group — concatenating the per-shard kernel outputs still equals
    the full kernel exactly (the kv=8-on-16-wide production layout)."""
    rng = np.random.default_rng(9)
    B, QH, KH, D, NP, PS, MP = 2, 8, 2, 32, 16, 8, 4
    n_shards, kv_rep = 4, 2                 # 4 chips, 2 kv heads -> rep 2
    q, k, v, ids, lens = make_case(rng, B, QH, KH, D, NP, PS, MP,
                                   jnp.float32)
    full = np.asarray(paged_attention(q, k, v, ids, lens, interpret=True))
    parts = []
    for s in range(n_shards):
        qs, ks, vs = shard_heads(q, k, v, s, n_shards, kv_rep=kv_rep)
        assert ks.shape[2] == 1             # one resident head per chip
        parts.append(np.asarray(
            paged_attention(qs, ks, vs, ids, lens, interpret=True)))
    # splitting a GQA group changes the kernel's f32 reduction shapes, so
    # (unlike the rep=1 slicing) equality holds to fp ulp, not bitwise
    np.testing.assert_allclose(np.concatenate(parts, axis=1), full,
                               rtol=1e-5, atol=1e-6)


def test_head_shard_rejects_indivisible():
    rng = np.random.default_rng(8)
    q, k, v, _, _ = make_case(rng, 1, 6, 2, 16, 8, 4, 2, jnp.float32)
    with pytest.raises(ValueError):
        shard_heads(q, k, v, 0, 4)
    # replication factor must exactly cover the shard count
    q, k, v, _, _ = make_case(rng, 1, 8, 2, 16, 8, 4, 2, jnp.float32)
    with pytest.raises(ValueError):
        shard_heads(q, k, v, 0, 8, kv_rep=2)   # 2*2 != 8


def test_shared_pages_prefix_cache():
    """Two sequences sharing physical pages (prefix caching) — indirection
    must read the same pool pages."""
    rng = np.random.default_rng(1)
    B, QH, KH, D, NP, PS, MP = 2, 2, 1, 16, 4, 4, 2
    q, k, v, _, _ = make_case(rng, B, QH, KH, D, NP, PS, MP, jnp.float32)
    ids = jnp.asarray([[0, 1], [0, 1]], jnp.int32)  # same pages
    lens = jnp.asarray([8, 8], jnp.int32)
    out_ref = paged_attention_ref(q, k, v, ids, lens)
    out_k = paged_attention(q, k, v, ids, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
