"""Correctness tests for the faithful concurrent simulator (Algorithms 1-6)."""
import numpy as np
import pytest

from repro.core import encoding as E
from repro.core import hashing as H
from repro.core import schedulers as S
from repro.core import simulator as sim
from repro.core.linearizability import check_history
from repro.core.spec import (OP_DELETE, OP_INSERT, OP_LOOKUP, RET_ABORT,
                             RET_FALSE, RET_PENDING, RET_TRUE,
                             apply_sequential)

MODES = [sim.MODE_LLSC, sim.MODE_CAS]


def run(wl, m, schedule, mode, seed=0, check_inv=False):
    st = sim.simulate(wl, m, schedule, mode=mode, hash_seed=seed,
                      check_inv=check_inv)
    return st


def finished(st, wl):
    res = np.asarray(st.results)
    op = np.asarray(wl.op)
    return np.all((res != RET_PENDING) | (op == -1))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", MODES)
def test_sequential_matches_spec(mode):
    """Single process, any schedule = sequential execution: results must
    exactly match the abstract dictionary."""
    rng = np.random.default_rng(0)
    for trial in range(10):
        K = 40
        wl = S.random_workload(rng, P=1, K=K, num_keys=8)
        m = 32
        sched = np.zeros(5000, dtype=np.int32)
        st = run(wl, m, sched, mode, seed=trial)
        assert finished(st, wl)
        _, expect = apply_sequential(
            [(int(wl.op[0, k]), int(wl.key[0, k])) for k in range(K)])
        got = list(np.asarray(st.results)[0])
        assert got == expect, f"trial {trial}: {got} vs {expect}"
        assert bool(st.pair_ok)


@pytest.mark.parametrize("mode", MODES)
def test_sequential_tombstone_reuse(mode):
    """insert/delete churn of distinct keys in a tiny table must never abort:
    tombstones are reused (the paper's headline difference vs [7,14])."""
    m = 8
    K = 64
    ops, keys = [], []
    for t in range(K // 2):
        ops += [OP_INSERT, OP_DELETE]
        keys += [100 + t, 100 + t]
    wl = sim.Workload(op=np.array([ops], dtype=np.int32),
                      key=np.array([keys], dtype=np.uint32))
    st = run(wl, m, np.zeros(4000, dtype=np.int32), mode)
    assert finished(st, wl)
    res = np.asarray(st.results)[0]
    assert np.all(res == RET_TRUE), res  # every insert & delete succeeds
    assert not np.any(res == RET_ABORT)


@pytest.mark.parametrize("mode", MODES)
def test_solo_insert_never_aborts_with_space(mode):
    """Proposition 2 corollary: a solo insert with a free/tombstone cell
    available does not abort."""
    rng = np.random.default_rng(3)
    m = 8
    # fill m-1 keys, delete some, then insert new ones
    ops = [OP_INSERT] * (m - 1) + [OP_DELETE] * 3 + [OP_INSERT] * 3
    keys = list(range(1, m)) + [1, 2, 3] + [50, 51, 52]
    wl = sim.Workload(op=np.array([ops], dtype=np.int32),
                      key=np.array([keys], dtype=np.uint32))
    st = run(wl, m, np.zeros(3000, dtype=np.int32), mode)
    assert finished(st, wl)
    res = np.asarray(st.results)[0]
    assert np.all(res == RET_TRUE)


@pytest.mark.parametrize("mode", MODES)
def test_abort_when_full(mode):
    """Insert into a truly full table returns ABORT and changes nothing."""
    m = 4
    ops = [OP_INSERT] * m + [OP_INSERT]
    keys = [1, 2, 3, 4, 99]
    wl = sim.Workload(op=np.array([ops], dtype=np.int32),
                      key=np.array([keys], dtype=np.uint32))
    st = run(wl, m, np.zeros(2000, dtype=np.int32), mode)
    assert finished(st, wl)
    res = np.asarray(st.results)[0]
    assert list(res[:m]) == [RET_TRUE] * m
    assert res[m] == RET_ABORT


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("sched_kind", ["uniform", "bursty", "stalled", "rr"])
def test_concurrent_linearizable(mode, sched_kind):
    """Random concurrent executions are linearizable and preserve the
    invariants (Lemma 4 + Proposition 3)."""
    rng = np.random.default_rng(hash((mode, sched_kind)) % 2**31)
    for trial in range(8):
        P, K, m = 3, 5, 16
        wl = S.random_workload(rng, P=P, K=K, num_keys=5)
        T = 4000
        if sched_kind == "uniform":
            sched = S.uniform_schedule(rng, P, T)
        elif sched_kind == "bursty":
            sched = S.bursty_schedule(rng, P, T)
        elif sched_kind == "stalled":
            sched = S.stalled_schedule(rng, P, T)
        else:
            sched = S.round_robin_schedule(P, T)
        st = run(wl, m, sched, mode, seed=trial, check_inv=True)
        assert bool(st.pair_ok), f"LL/SC pairing violated ({mode},{trial})"
        assert bool(st.inv_ok), f"Lemma4/Prop3 violated ({mode},{trial})"
        rows = sim.history_arrays(st, wl)
        ok, bad = check_history(rows)
        assert ok, (f"non-linearizable keys {bad} ({mode},{sched_kind},"
                    f"{trial}): {rows}")


@pytest.mark.parametrize("mode", MODES)
def test_same_key_stress(mode):
    """All processes hammer one key (Figure 2 scenarios): duplicate copies
    must be resolved; history must remain linearizable."""
    rng = np.random.default_rng(7)
    for trial in range(10):
        P, K, m = 3, 4, 8
        wl = S.same_key_workload(P, K, key=5, pattern="insert_delete")
        sched = S.uniform_schedule(rng, P, 6000)
        st = run(wl, m, sched, mode, seed=trial, check_inv=True)
        assert bool(st.inv_ok)
        assert bool(st.pair_ok)
        rows = sim.history_arrays(st, wl)
        ok, bad = check_history(rows)
        assert ok, f"({mode}, trial {trial}): {rows}"
        # after everything completes, at most one copy of the key remains
        if finished(st, wl):
            tab = np.asarray(st.table)
            copies = np.sum(E.dec_key(tab) == 5)
            assert copies <= 1, tab


@pytest.mark.parametrize("mode", MODES)
def test_step_accounting(mode):
    """Each completed op consumed >= 2 memory events (scan + action)."""
    rng = np.random.default_rng(11)
    wl = S.random_workload(rng, P=2, K=6, num_keys=4)
    st = run(wl, 16, S.uniform_schedule(rng, 2, 3000), mode)
    steps = np.asarray(st.steps)
    res = np.asarray(st.results)
    assert np.all(steps[res != RET_PENDING] >= 1)
    assert steps.sum() <= 3000


def test_encoding_roundtrip():
    for v in [0, 1, 12345, E.MAX_KEY]:
        assert int(E.dec_key(E.enc_tentative(v))) == v
        assert int(E.dec_tag(E.enc_final(v))) == E.TAG_FINAL
        assert bool(E.restart(E.enc_revalidate(v)))
        assert bool(E.is_marked(E.enc_marked(v)))
        assert not bool(E.is_marked(E.enc_revalidate(v)))
    for c in [E.EMPTY, E.TOMBSTONE, E.DELETED, E.COLLIDED]:
        assert int(E.dec_key(np.uint32(c))) == E.RESERVED_KEY
        assert not bool(E.restart(np.uint32(c)))
    assert bool(E.is_available(np.uint32(E.EMPTY)))
    assert bool(E.is_available(np.uint32(E.TOMBSTONE)))
    assert not bool(E.is_available(np.uint32(E.DELETED)))


def test_cell_size_accounting():
    """Theorem 1 bit counts."""
    cs = E.cell_size_llsc(U=2**20)
    assert cs.total == 21 + 2 == 23  # ceil(log2(2^20+1)) = 21
    cs2 = E.cell_size_cas(U=2**20, n=64, m=2**16)
    assert cs2.owner_bits == 6
    assert cs2.total == 21 + 2 + 6


def test_hashing_range():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**28 - 2, size=1000).astype(np.uint32)
    for m in [16, 64, 100, 1 << 12]:
        h = np.asarray(H.hash_keys(keys, m, seed=3))
        assert h.min() >= 0 and h.max() < m
    # determinism + seed sensitivity
    h1 = np.asarray(H.hash_keys(keys, 64, seed=1))
    h2 = np.asarray(H.hash_keys(keys, 64, seed=2))
    assert not np.array_equal(h1, h2)
