"""The distributed page table: hash-prefix routing (``dist/table_shard``),
the lazy incremental resize with its recorded-trace lookup parity, the
per-shard headroom invariant, the sharded checkpoint, and the simulated
multi-host storm (``tests/_multihost``) as a pytest entry point."""
from __future__ import annotations

import hashlib

import numpy as np

import jax.numpy as jnp

import _multihost as MH
from repro.core import batched as BT
from repro.core import encoding as E
from repro.dist import table_shard as TS
from repro.serving import page_table as PT
from repro.serving.sched import synthetic_workload
from repro.serving.sharded_table import (ShardedPageTable, checkpoint_sharded,
                                         plan_table_shards,
                                         restore_sharded_table)


# --- manifest routing ------------------------------------------------------

def test_manifest_balanced_routing():
    man = TS.ShardManifest.balanced(4)
    seqs = np.arange(1, 1025, dtype=np.uint32)
    owners = man.owner_of_seq(seqs)
    counts = np.bincount(owners, minlength=4)
    assert counts.sum() == 1024 and (counts > 128).all(), counts
    # routing is a pure function of the id — stable across calls
    assert (man.owner_of_seq(seqs) == owners).all()


def test_manifest_reassign_keeps_survivor_prefixes():
    man = TS.ShardManifest.balanced(4)
    new = man.reassign(2)
    assert 2 not in new.live_shards() and new.live_shards() == (0, 1, 3)
    for p, o in enumerate(man.owners):
        if o != 2:      # survivors keep their ranges — live seqs undisturbed
            assert new.owners[p] == o
        else:
            assert new.owners[p] in (0, 1, 3)
    # down to one survivor is allowed; reassigning the last one is not
    last = new.reassign(0).reassign(1)
    assert last.live_shards() == (3,)
    try:
        last.reassign(3)
        assert False, "reassigning the last shard must raise"
    except ValueError:
        pass


def test_manifest_json_roundtrip():
    man = TS.ShardManifest.balanced(3).reassign(1)
    back = TS.ShardManifest.from_json(man.to_json())
    assert back == man


def test_plan_table_shards():
    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape
    assert plan_table_shards(FakeMesh({"pod": 2, "data": 16})) == 2
    assert plan_table_shards(FakeMesh({"data": 16, "model": 16})) == 1
    assert plan_table_shards(object()) == 1


# --- lazy incremental resize ----------------------------------------------

def _trace_replay(grow_at, strategy="linear"):
    """Drive one shard through a deterministic mixed op trace, growing
    lazily at round ``grow_at`` (None = never, big table from the start);
    record a digest of every round's lookup answers over a fixed probe set."""
    rng = np.random.default_rng(7)
    m0 = 256 if grow_at is None else 64
    shard = TS.TableShard.create(0, m0, seed=3, strategy=strategy)
    universe = rng.choice(4096, size=96, replace=False).astype(np.uint32)
    live: set = set()
    trace = []
    migrating_rounds = 0
    for rnd in range(14):
        if rnd == grow_at:
            shard = shard.begin_migration(256)
        fresh = [k for k in universe if k not in live][:6]
        shard, ret, _ = shard.insert(jnp.asarray(fresh, jnp.uint32))
        assert not int(np.asarray(ret == 2).sum()), "unexpected ABORT"
        live |= set(int(k) for k in fresh)
        drops = rng.choice(sorted(live), size=3, replace=False)
        shard, _, _ = shard.delete(jnp.asarray(drops, jnp.uint32))
        live -= set(int(k) for k in drops)
        # slow sweep so the migration stays in flight across many rounds
        shard, _ = shard.sweep_migrate(8)
        migrating_rounds += int(shard.migrating)
        found, _, _ = shard.find(jnp.asarray(universe))
        found = np.asarray(found)
        assert set(universe[found].tolist()) == live
        trace.append(hashlib.sha256(found.tobytes()).hexdigest())
    return trace, migrating_rounds, shard


def test_lazy_resize_recorded_trace_parity():
    """Lookups answer-identically THROUGHOUT the migration: the recorded
    per-round answer trace of the lazily-growing shard equals the trace of
    a shard that had the full capacity from round 0."""
    lazy, mig_rounds, shard = _trace_replay(grow_at=2)
    eager, _, _ = _trace_replay(grow_at=None)
    assert lazy == eager
    # the parity must actually have spanned a live migration, and the
    # sweep must have finished it
    assert mig_rounds >= 3 and not shard.migrating


def test_lazy_resize_trace_parity_hopscotch():
    lazy, mig_rounds, shard = _trace_replay(grow_at=2, strategy="hopscotch")
    eager, _, _ = _trace_replay(grow_at=None, strategy="hopscotch")
    assert lazy == eager and mig_rounds >= 3 and not shard.migrating


def test_migration_headroom_invariant():
    """``free_cells = m_new - live_new - live_old`` through the whole
    migration — and inserting exactly ``free_cells`` fresh keys mid-flight
    never ABORTs (the committed-cells argument the per-shard admission
    proof leans on)."""
    shard = TS.TableShard.create(0, 32, seed=1)
    shard, _, _ = shard.insert(jnp.arange(100, 120, dtype=jnp.uint32))
    shard = shard.begin_migration(64)
    assert shard.free_cells() == 64 - 20
    # interleave sweeps with inserts; the invariant holds at every step
    fresh = iter(range(200, 400))
    while shard.migrating:
        shard, _ = shard.sweep_migrate(4)
        ks = jnp.asarray([next(fresh) for _ in range(2)], jnp.uint32)
        shard, ret, _ = shard.insert(ks)
        assert not int(np.asarray(ret == 2).sum())
        live_new = int(shard.table.num_keys)
        live_old = 0 if shard.old is None else int(shard.old.num_keys)
        assert shard.free_cells() == 64 - live_new - live_old
    # stable again: fill to the brim with zero ABORTs
    room = shard.free_cells()
    ks = jnp.asarray([next(fresh) for _ in range(room)], jnp.uint32)
    shard, ret, _ = shard.insert(ks)
    assert int(np.asarray(ret == 1).sum()) == room
    assert shard.free_cells() == 0


def test_moved_markers():
    """Every migrated entry leaves its marker: TOMBSTONE + meta bit for the
    metadata-free strategies; the EMPTY cell itself under hopscotch."""
    shard = TS.TableShard.create(0, 32, seed=2)
    keys = jnp.arange(50, 60, dtype=jnp.uint32)
    shard, _, _ = shard.insert(keys)
    shard = shard.begin_migration(64)
    _, old_slots = BT.find_batch(shard.old, keys)
    shard, moves = shard.migrate_keys(keys[:4])
    assert moves.n == 4
    tab = np.asarray(shard.old.table)
    meta = np.asarray(shard.old.meta)
    for s in np.asarray(old_slots)[:4]:
        assert tab[s] == E.TOMBSTONE
        assert meta[s // 32] & (1 << (s % 32))
    for s in np.asarray(old_slots)[4:]:       # unmigrated: no marker yet
        assert not (meta[s // 32] & (1 << (s % 32)))

    hop = TS.TableShard.create(0, 32, seed=2, strategy="hopscotch")
    hop, _, _ = hop.insert(keys)
    hop = hop.begin_migration(64)
    _, old_slots = BT.find_batch(hop.old, keys, strategy="hopscotch")
    hop, moves = hop.migrate_keys(keys[:4])
    assert moves.n == 4
    tab = np.asarray(hop.old.table)
    assert all(tab[s] == E.EMPTY for s in np.asarray(old_slots)[:4])


def test_migration_moves_carry_pages():
    """MoveSet parity: applying the (src, dst) moves to a shadow page map
    keeps every key's page addressable at the slot ``find`` reports."""
    shard = TS.TableShard.create(0, 64, seed=5)
    keys = jnp.arange(300, 340, dtype=jnp.uint32)
    shard, _, _ = shard.insert(keys)
    _, slots = BT.find_batch(shard.table, keys)
    pages = {int(s): int(k) for s, k in zip(np.asarray(slots),
                                            np.asarray(keys))}
    old_pages = dict(pages)
    shard = shard.begin_migration(128)
    new_pages: dict = {}
    while shard.migrating:
        shard, mv = shard.sweep_migrate(8)
        for src, dst in zip(mv.old_slots, mv.new_slots):
            new_pages[int(dst)] = old_pages.pop(int(src))
    assert not old_pages and len(new_pages) == 40
    found, slots, in_old = shard.find(keys)
    assert bool(np.asarray(found).all()) and not bool(np.asarray(in_old).any())
    for s, k in zip(np.asarray(slots), np.asarray(keys)):
        assert new_pages[int(s)] == int(k)


# --- the routed facade -----------------------------------------------------

def test_sharded_alloc_routes_to_owners():
    spt = ShardedPageTable(4, 32, page_size=4, max_pages=8)
    seqs = np.arange(1, 13, dtype=np.uint32)
    owners = spt.owner_of_seq(seqs)
    pos = np.zeros(12, np.int64)
    ws, ab, moves = spt.alloc_step(seqs, pos)
    assert not moves and not ab.any() and (ws >= 0).all()
    assert np.unique(ws).size == 12
    for slot, sid in zip(ws, owners):
        st = spt._shards[int(sid)]
        assert st.cur.start <= slot < st.cur.start + st.cur.size
    # every shard's headroom speaks the scheduler's Headroom dialect
    for sid in spt.live_shards():
        h = spt.headroom(sid)
        assert h.free_cells == 32 - h.live_pages and h.strategy == "linear"


def test_sharded_lose_shard_reroutes():
    spt = ShardedPageTable(3, 32, page_size=4, max_pages=8)
    seqs = np.arange(1, 10, dtype=np.uint32)
    spt.alloc_step(seqs, np.zeros(9, np.int64))
    lost = spt.live_shards()[-1]
    lost_live = spt._shards[lost].shard.live_pages()
    before = spt.total_live_pages()
    spt.lose_shard(lost)
    assert lost not in spt.live_shards()
    assert spt.total_live_pages() == before - lost_live
    # the dead shard's sequences now route to survivors
    assert lost not in set(spt.owner_of_seq(seqs).tolist())


# --- sharded checkpoint ----------------------------------------------------

def test_checkpoint_restore_other_shard_count(tmp_path):
    spt = ShardedPageTable(4, 48, page_size=4, max_pages=8)
    seqs = np.arange(1, 17, dtype=np.uint32)
    for pos in range(8):
        spt.alloc_step(seqs, np.full(16, pos, np.int64))
    spt.grow_shard(spt.live_shards()[0], 96)   # save MID-migration
    n_live = spt.total_live_pages()
    checkpoint_sharded(spt, str(tmp_path), step=5)

    for n_shards in (2, 3):
        back, step = restore_sharded_table(str(tmp_path), n_shards, 96,
                                           page_size=4, max_pages=8)
        assert step == 5 and back.total_live_pages() == n_live
        bt = back.lookup_pages(seqs, np.full(16, 7, np.int64))
        assert (bt[:, :2] >= 0).all() and (bt[:, 2:] == -1).all()


def test_checkpoint_recommit_after_remesh(tmp_path):
    """The re-save path: losing a shard after the commit re-commits the
    SAME step with the reassigned manifest (atomic shards.json replace)."""
    import json
    import os
    spt = ShardedPageTable(3, 32, page_size=4, max_pages=8)
    spt.alloc_step(np.arange(1, 7, dtype=np.uint32), np.zeros(6, np.int64))
    checkpoint_sharded(spt, str(tmp_path), step=1)
    spt.lose_shard(spt.live_shards()[-1])
    path = checkpoint_sharded(spt, str(tmp_path), step=1)
    with open(path) as f:
        doc = json.load(f)
    man = TS.ShardManifest(int(doc["shard_manifest"]["prefix_bits"]),
                           tuple(doc["shard_manifest"]["owners"]))
    assert man == spt.manifest and len(man.live_shards()) == 2
    assert os.path.basename(path) == "shards.json"


# --- the simulated multi-host storm (pytest entry point) -------------------

def test_multihost_storm_grow_and_loss():
    """Small edition of the CI shard-soak: 2x-overcommitted storm with a
    forced lazy resize and a host-group loss; every request completes, 0
    proactive aborts, shadow map and counters stay consistent (verified
    in-storm every other round)."""
    cluster = MH.SimCluster(hosts=2, pages_per_shard=24, slots_per_shard=3,
                            page_size=4, max_len=16, megastep_k=4,
                            fail_on_abort=True)
    wl = synthetic_workload(10, vocab_size=64, max_len=16, seed=0,
                            prompt_len=(2, 4), max_new=(6, 10))
    s = cluster.run_storm(wl, max_rounds=200, grow_round=1, lose_round=3)
    assert int(s["completed"]) == int(s["submitted"]) == 10
    assert int(s["aborts_observed"]) == 0
    assert int(s["rehomed"]) >= 0 and int(s["live_shards"]) == 1


def test_probe_stats_cover_routed_ops():
    PT.probe_stats_reset()
    spt = ShardedPageTable(2, 16, page_size=4, max_pages=4)
    seqs = np.arange(1, 5, dtype=np.uint32)
    spt.alloc_step(seqs, np.zeros(4, np.int64))
    spt.lookup_pages(seqs, np.zeros(4, np.int64))
    assert PT.PROBE_STATS["keys_probed"] > 0
    PT.probe_stats_reset()
