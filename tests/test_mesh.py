"""Multi-device tests (8 fake CPU devices via subprocess — the main pytest
process stays single-device per the dry-run isolation rule).

Covers the real shard_map paths: MoE dispatch, paged attention, the
compressed manual-pod train step, GPipe pipeline, and elastic restore onto a
different mesh."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(script: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
"""


def test_moe_sharded_matches_single():
    run_with_devices(COMMON + """
from repro.configs import get_smoke_config
from repro.dist import ctx
from repro.dist.sharding import train_rules
from repro.models import moe as MOE
cfg = get_smoke_config("granite-moe-1b-a400m")   # 4 experts
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
p, a = MOE.moe_init(key, cfg, jnp.float32)
x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
y0, aux0 = MOE.moe_apply(p, x, cfg)                       # single-shard path
with ctx.use_rules(train_rules(mesh)):
    y1, aux1 = jax.jit(lambda p, x: MOE.moe_apply(p, x, cfg))(p, x)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-5,
                           rtol=1e-4)
print("moe sharded == single OK")
""")


def test_paged_decode_sharded_matches_single():
    run_with_devices(COMMON + """
from repro.configs import get_smoke_config
from repro.dist.sharding import serve_rules
from repro.models.registry import get_model
from repro.serving import engine as EG
cfg = get_smoke_config("qwen2.5-32b")   # 8 q heads, kv 2
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = serve_rules(mesh)
model = get_model(cfg)
params, _ = model.init(cfg, jax.random.PRNGKey(0))
B, T = 2, 10
toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)

def run(rules):
    state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4,
                                    rules=rules)
    step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4,
                                      rules=rules))
    outs = []
    for t in range(T):
        pos = jnp.full((B,), t, jnp.int32)
        lg, state = step(params, state, toks[:, t:t+1], pos)
        outs.append(np.asarray(lg))
    return np.stack(outs)

ref = run(None)
shd = run(rules)
np.testing.assert_allclose(shd, ref, atol=5e-2, rtol=1e-2)
print("paged decode sharded == single OK, maxerr",
      float(np.abs(shd - ref).max()))
""")


def test_manual_pod_compressed_step():
    run_with_devices(COMMON + """
from repro.configs import get_smoke_config
from repro.dist.sharding import train_rules
from repro.training import train_step as TS
from repro.training import data as D
cfg = get_smoke_config("codeqwen1.5-7b")
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = train_rules(mesh)
state, axes = TS.init_state(cfg, jax.random.PRNGKey(0))
err = TS.init_pod_error_buffers(state.params, 2)
step = TS.make_train_step_manual_pod(cfg, mesh, rules=rules)
b = D.synth_batch(cfg, batch=4, seq_len=16, step=0)
state2, err2, metrics = jax.jit(step)(state, err, b)
assert np.isfinite(float(metrics["loss"])), metrics
# compare against the plain GSPMD step on the same batch: compressed-DP
# loss must match exactly (loss is computed before any compression)
plain = TS.make_train_step(cfg, rules=None)
_, m2 = jax.jit(plain)(state, b)
# bf16 graphs differ (pod-sharded batch order, compressed grads touch the
# metrics only post-loss): loss agrees to bf16 noise
np.testing.assert_allclose(float(metrics["loss"]), float(m2["loss"]),
                           rtol=2e-3)
print("manual-pod compressed step OK, loss", float(metrics["loss"]))
""")


def test_pipeline_matches_sequential():
    run_with_devices(COMMON + """
from repro.dist import pipeline as PL
mesh = jax.make_mesh((4, 2), ("pod", "data"))
L, d = 8, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, d, d)) * 0.1

class Cfg: num_layers = L
def apply_range(w_stack, x):
    def body(x, w):
        return jnp.tanh(x @ w), None
    x, _ = jax.lax.scan(body, x, w_stack)
    return x

fwd = PL.make_pipelined_forward(Cfg, mesh, apply_range, microbatches=4)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d))
y_pipe = jax.jit(fwd)(ws, x)
y_seq = apply_range(ws, x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                           atol=1e-5, rtol=1e-5)
print("gpipe == sequential OK; bubble",
      PL.bubble_fraction(4, 4))
""")


def test_elastic_restore_new_mesh(tmp_path):
    run_with_devices(COMMON + f"""
import os
from repro.configs import get_smoke_config
from repro.dist.sharding import train_rules
from repro.training import checkpoint as CKPT
from repro.training import train_step as TS
cfg = get_smoke_config("qwen2.5-32b")
state, axes = TS.init_state(cfg, jax.random.PRNGKey(0))
CKPT.save({str(tmp_path)!r}, 5, state, axes)
# restore onto a DIFFERENT mesh shape (elastic resize 8 -> 4+4)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = train_rules(mesh)
restored, step = CKPT.restore({str(tmp_path)!r}, state, rules=rules)
assert step == 5
leaf = jax.tree.leaves(restored)[0]
assert len(leaf.sharding.device_set) >= 1
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
print("elastic restore OK")
""")


def test_manual_tp_matches_baseline():
    run_with_devices(COMMON + """
import dataclasses
from repro.configs import get_smoke_config
from repro.dist import ctx
from repro.dist import tp as TP
from repro.dist.sharding import train_rules
from repro.models import layers as L
cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                          dtype="float32", tp_impl="manual")
mesh = jax.make_mesh((4, 2), ("data", "model"))   # tp=2 divides q=8, kv=2
key = jax.random.PRNGKey(0)
p, _ = L.block_init(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
positions = jnp.arange(16)[None, :]
ref = L.block_apply(p, x, positions, cfg)
with ctx.use_rules(train_rules(mesh)):
    got = jax.jit(lambda p, x: TP.block_apply_tp(cfg, p, x, positions))(p, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5,
                           rtol=1e-4)
print("manual TP == baseline OK")
""")


def test_manual_decode_matches_gspmd():
    """The fused manual-TP decode step (one shard_map over all axes,
    head-sharded KV pools) matches the GSPMD decode path on an 8-device
    mesh — dense (pod/data/model), MoE (expert-parallel), int8-KV,
    non-divisible GQA (kv=2 on a 4-wide model axis -> KV replication),
    gemma3 local-window ring layers, and the zamba2 hybrid family.

    The MoE router carries a deterministic snap+index tie-break
    (moe._router_top_k), so impls on the same mesh can no longer flip
    experts on bf16 near-ties — the old top-2-gap-aware token allowance
    (0.12-wide, sized for whole-expert flips) is gone; parity is the plain
    allclose at fp-noise tolerance for every family."""
    run_with_devices(COMMON + """
import dataclasses
from repro.configs import get_smoke_config
from repro.dist import tp as TP
from repro.dist.sharding import serve_rules, serve_manual_rules
from repro.models.registry import get_model
from repro.serving import engine as EG

CASES = [
    ("qwen2.5-32b", (2, 2, 2), ("pod", "data", "model"), {}),
    ("granite-moe-1b-a400m", (4, 2), ("data", "model"), {}),
    ("qwen2.5-32b", (4, 2), ("data", "model"), {"kv_cache_dtype": "int8"}),
    # kv=2 on tp=4: the KV-replication path (rep=2), previously a fallback
    ("qwen2.5-32b", (2, 4), ("data", "model"), {}),
    # local-window ring layers inside the fused region
    ("gemma3-12b", (2, 2, 2), ("pod", "data", "model"), {}),
    # hybrid: mamba backbone HEAD-SHARDED over model (decode_ssm_tp) +
    # Megatron-sharded shared attn block
    ("zamba2-1.2b", (4, 2), ("data", "model"), {}),
]
for arch, shape, axes, over in CASES:
    cfg = dataclasses.replace(get_smoke_config(arch), **over)
    mesh = jax.make_mesh(shape, axes)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, T = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)

    def run(c, r):
        state, _ = EG.make_decode_state(c, B, S_max=32, page_size=4, rules=r)
        step = jax.jit(EG.make_serve_step(c, S_max=32, page_size=4, rules=r))
        outs = []
        for t in range(T):
            pos = jnp.full((B,), t, jnp.int32)
            lg, state = step(params, state, toks[:, t:t+1], pos)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    man_cfg = dataclasses.replace(cfg, tp_impl="manual")
    man_rules = serve_manual_rules(mesh)
    assert EG._manual_decode_ok(man_cfg, man_rules), (arch, "gate refused")
    if cfg.family == "hybrid":
        # the mamba math must take the SHARDED path on this mesh (tp=2),
        # so the parity below covers it against the gspmd/replicated impls
        assert TP.decode_ssm_tp(man_cfg, mesh.shape["model"])
    gspmd = run(cfg, serve_rules(mesh))
    manual = run(man_cfg, man_rules)
    np.testing.assert_allclose(manual, gspmd, atol=5e-2, rtol=1e-2,
                               err_msg=arch)
    if cfg.family == "dense" and not over:
        ref = run(cfg, None)
        np.testing.assert_allclose(manual, ref, atol=5e-2, rtol=1e-2)
    print(arch, shape, over, "manual == gspmd OK, maxerr",
          float(np.abs(manual - gspmd).max()))
print("fused manual decode == gspmd OK")
""")


def test_megastep_matches_single_steps_multidevice():
    """The K=8 decode megastep is BITWISE-identical (greedy tokens + final
    state) to 8 single steps on an 8-device mesh, for BOTH decode families:
    the gspmd step and the fused manual-TP region (where the whole scan
    lives inside the one fully-manual shard_map).  Covers dense, MoE,
    int8-KV, gemma3 local-window rings and the zamba2 hybrid."""
    run_with_devices(COMMON + """
import dataclasses
from repro.configs import get_smoke_config
from repro.dist.sharding import serve_rules, serve_manual_rules
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT

CASES = [
    ("qwen2.5-32b", (2, 2, 2), ("pod", "data", "model"), {}),
    ("granite-moe-1b-a400m", (4, 2), ("data", "model"), {}),
    ("qwen2.5-32b", (4, 2), ("data", "model"), {"kv_cache_dtype": "int8"}),
    ("gemma3-12b", (2, 2, 2), ("pod", "data", "model"), {}),
    ("zamba2-1.2b", (4, 2), ("data", "model"), {}),
]
B, K = 2, 8
for arch, shape, axes, over in CASES:
    base = dataclasses.replace(get_smoke_config(arch), **over)
    mesh = jax.make_mesh(shape, axes)
    model = get_model(base)
    params, _ = model.init(base, jax.random.PRNGKey(0))
    tok0 = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                              base.vocab_size)
    for impl, mk_rules in (("gspmd", serve_rules),
                           ("manual", serve_manual_rules)):
        cfg = (dataclasses.replace(base, tp_impl="manual")
               if impl == "manual" else base)
        rules = mk_rules(mesh)
        if impl == "manual":
            assert EG._manual_decode_ok(cfg, rules), (arch, "gate refused")
        state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4,
                                        rules=rules)
        step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4,
                                          rules=rules))
        st, tok, ref = dict(state), tok0, []
        for _ in range(K):
            lg, st = step(params, st, tok, st["pos"])
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
            tok = jnp.where(st["aborted"][:, None], tok, nxt)
            ref.append(np.asarray(tok[:, 0]))
        ref = np.stack(ref, axis=1)
        state2, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4,
                                         rules=rules)
        mega = jax.jit(EG.make_serve_megastep(cfg, S_max=32, K=K,
                                              page_size=4, rules=rules))
        mtoks, mst = mega(params, state2, tok0)
        np.testing.assert_array_equal(np.asarray(mtoks), ref,
                                      err_msg=f"{arch}/{impl}")
        for k in st:
            ok = all(jax.tree.leaves(jax.tree.map(
                lambda x, y: bool(np.array_equal(np.asarray(x),
                                                 np.asarray(y))),
                st[k], mst[k])))
            assert ok, (arch, impl, k, "state leaf diverged")
        assert int(PT.for_strategy("linear").verify_block_table(
            mst["table"], mst["seq_ids"], mst["pos"], mst["block_table"],
            page_size=4)) == 0, (arch, impl)
    print(arch, shape, over, "megastep == single steps OK (gspmd+manual)")
print("megastep parity multidevice OK")
""")


def test_sharded_dht_roundtrip():
    run_with_devices(COMMON + """
from repro.core import sharded as SHT
from repro.core.spec import OP_INSERT, OP_LOOKUP, OP_DELETE
mesh = jax.make_mesh((8,), ("model",))
st, apply_fn = SHT.make_sharded_table(mesh, "model", m_global=1024,
                                      capacity=64)
B = 128
keys = jnp.arange(B, dtype=jnp.uint32) * 7
ops = jnp.full((B,), OP_INSERT, jnp.int32)
st, ret, ovf = apply_fn(st, ops, keys)
assert int(ret.sum()) == B, ret
st, ret, _ = apply_fn(st, jnp.full((B,), OP_LOOKUP, jnp.int32), keys)
assert int(ret.sum()) == B
st, ret, _ = apply_fn(st, jnp.full((B,), OP_DELETE, jnp.int32), keys)
assert int(ret.sum()) == B
st, ret, _ = apply_fn(st, jnp.full((B,), OP_LOOKUP, jnp.int32), keys)
assert int(ret.sum()) == 0
print("sharded DHT OK")
""")
