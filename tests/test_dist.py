"""Tests for the ``repro.dist`` subsystem: rules context nesting/restore,
``shard_act`` as identity outside a mesh, spec resolution (divisibility,
no mesh-axis reuse), TP block application matching the plain
``models.layers`` path numerically on CPU, and the compression/pipeline
helpers that do not need a multi-device mesh (those run in
``test_mesh.py``)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.dist import compression as COMP
from repro.dist import ctx
from repro.dist import pipeline as PL
from repro.dist import tp as TP
from repro.dist.sharding import ShardingRules, dp_rules, serve_manual_rules, \
    serve_rules, train_rules
from repro.models import layers as L


def _mesh_1x1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


# ---------------------------------------------------------------------------
# ctx: nesting / restore / identity.

def test_use_rules_nesting_and_restore():
    assert ctx.current_rules() is None
    r1 = train_rules(_mesh_1x1())
    r2 = serve_rules(_mesh_1x1())
    with ctx.use_rules(r1):
        assert ctx.current_rules() is r1
        with ctx.use_rules(r2):
            assert ctx.current_rules() is r2
            # None explicitly clears (single-device code paths key on it)
            with ctx.use_rules(None):
                assert ctx.current_rules() is None
            assert ctx.current_rules() is r2
        assert ctx.current_rules() is r1
    assert ctx.current_rules() is None


def test_use_rules_restores_on_exception():
    r1 = train_rules(_mesh_1x1())
    with pytest.raises(RuntimeError):
        with ctx.use_rules(r1):
            raise RuntimeError("boom")
    assert ctx.current_rules() is None


def test_shard_act_identity_outside_mesh():
    x = jnp.ones((4, 8, 16))
    y = ctx.shard_act(x, ("batch", "seq", None))
    assert y is x            # no rules active -> exact identity, no op added


def test_shard_act_identity_when_spec_replicated():
    # 1x1 mesh: every mapping fails divisibility-or-size>1 -> replicated
    with ctx.use_rules(train_rules(_mesh_1x1())):
        x = jnp.ones((3, 5, 7))
        y = ctx.shard_act(x, ("batch", "seq", None))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# ShardingRules.spec resolution.

def _fake_mesh_rules():
    """Rules over an abstract 2x4 mesh (no devices needed for spec logic)."""
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    return ShardingRules(mesh=mesh, rules={
        "batch": ("pod", "data"), "heads": ("model",), "kv": ("model",),
        "embed": ("data",), "vocab": ("model",),
    })


def test_spec_divisibility_gates_mapping():
    r = _fake_mesh_rules()
    # batch 6 % data 2 == 0 -> sharded; heads 6 % model 4 != 0 -> replicated
    assert r.spec(("batch", "heads"), (6, 6)) == P("data")
    assert r.spec(("batch", "heads"), (6, 8)) == P("data", "model")
    # absent mesh axis ("pod") is skipped silently
    assert r.spec(("batch",), (8,)) == P("data")


def test_spec_never_reuses_a_mesh_axis():
    r = _fake_mesh_rules()
    # heads and kv both want "model": first dim wins, second replicated
    assert r.spec(("heads", "kv"), (8, 8)) == P("model")


def test_spec_exclude_manual_axes():
    r = _fake_mesh_rules()
    assert r.spec(("batch", "heads"), (6, 8),
                  exclude=frozenset({"data"})) == P(None, "model")
    assert r.drop("model").spec(("heads",), (8,)) == P()


def test_axis_for_experts_contract():
    """models/moe.py keys expert parallelism off axis_for("experts", E)."""
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    r = train_rules(mesh)
    assert r.axis_for("experts", 8) == "model"
    assert r.axis_for("experts", 6) is None        # 6 % 4 != 0
    assert dp_rules(mesh).axis_for("experts", 8) is None


def test_tree_shardings_handles_scalars_and_tuples():
    r = train_rules(_mesh_1x1())
    axes = {"w": ("embed", "heads"), "step": (), "nested": {"b": None}}
    sds = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
           "step": jax.ShapeDtypeStruct((), jnp.int32),
           "nested": {"b": jax.ShapeDtypeStruct((3,), jnp.float32)}}
    out = r.tree_shardings(axes, sds)
    assert out["step"].spec == P()
    assert out["nested"]["b"].spec == P()


# ---------------------------------------------------------------------------
# TP block application == plain layers path.

@pytest.mark.parametrize("tp_impl", ["gspmd", "manual"])
def test_block_apply_tp_matches_layers(tp_impl):
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                              dtype="float32", tp_impl=tp_impl)
    key = jax.random.PRNGKey(0)
    p, _ = L.block_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    positions = jnp.arange(16)[None, :]
    ref = L.block_apply(p, x, positions, cfg)

    # outside any mesh: both impls must be the identical baseline path
    got = TP.block_apply_tp(cfg, p, x, positions)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    # under a 1-wide model axis the manual shard_map path is exercised but
    # must still match the un-TP'd reference numerically
    with ctx.use_rules(train_rules(_mesh_1x1())):
        got = jax.jit(lambda p, x: TP.block_apply_tp(cfg, p, x, positions))(
            p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_attn_apply_tp_matches_layers():
    from repro.models import nn
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                              dtype="float32")
    key = jax.random.PRNGKey(0)
    p, _ = L.block_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    positions = jnp.arange(16)[None, :]
    ref = x + L.self_attention(p["attn"], nn.rmsnorm(p["ln1"], x),
                               positions, cfg)
    got = TP.attn_apply_tp(cfg, p, x, positions)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# Decode-side manual TP: gate + layout rules.

def test_decode_manual_tp_gate():
    """decode_manual_tp: tp_impl/mesh/divisibility gating, tp==1 allowed,
    refusal inside an enclosing manual region (serving/engine keys the fused
    decode region off this)."""
    mesh42 = jax.sharding.AbstractMesh((("data", 4), ("model", 2)))
    mesh24 = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    dense = get_smoke_config("qwen2.5-32b")          # n_q=8, n_kv=2
    man = dataclasses.replace(dense, tp_impl="manual")
    assert TP.decode_manual_tp(dense, serve_manual_rules(mesh42)) == 0
    assert TP.decode_manual_tp(man, None) == 0
    assert TP.decode_manual_tp(man, serve_manual_rules(mesh42)) == 2
    # kv=2 on a 4-wide model axis: REPLICATED (rep=2), no longer a fallback
    assert TP.decode_manual_tp(man, serve_manual_rules(mesh24)) == 4
    assert TP.decode_kv_rep(man, 4) == 2
    assert TP.decode_kv_rep(man, 2) == 1
    # n_q must still divide, and kv must divide or be divided by tp
    assert TP.decode_manual_tp(
        dataclasses.replace(man, pad_heads_to=9),
        serve_manual_rules(mesh24)) == 0
    assert TP.decode_kv_rep(dataclasses.replace(man, pad_kv_to=3), 4) == 0
    assert TP.decode_manual_tp(
        dataclasses.replace(man, pad_kv_to=3), serve_manual_rules(mesh24)) == 0
    assert TP.decode_manual_tp(
        dataclasses.replace(man, d_ff=191), serve_manual_rules(mesh42)) == 0
    # every refusal has a loggable reason; applicability has none
    assert TP.decode_manual_unsupported(man, serve_manual_rules(mesh42)) is None
    assert "d_ff" in TP.decode_manual_unsupported(
        dataclasses.replace(man, d_ff=191), serve_manual_rules(mesh42))
    # tp == 1 still takes the fused path (single-device CPU coverage)
    assert TP.decode_manual_tp(man, serve_manual_rules(_mesh_1x1())) == 1
    # MoE gates on expert divisibility instead of d_ff
    moe = dataclasses.replace(get_smoke_config("granite-moe-1b-a400m"),
                              tp_impl="manual")
    assert TP.decode_manual_tp(moe, serve_manual_rules(mesh42)) == 2
    assert TP.decode_manual_tp(
        dataclasses.replace(moe, num_experts=3),
        serve_manual_rules(mesh42)) == 0
    # inside a region that already owns the model axis: refuse
    with ctx.manual_axes({"model"}):
        assert TP.decode_manual_tp(man, serve_manual_rules(mesh42)) == 0


def test_decode_ssm_tp_gate():
    """decode_ssm_tp: the hybrid mamba backbone shards its per-head dims
    over model iff B/C streams are shared (ssm_groups == 1) and the head
    count divides; tp == 1 passes for CPU coverage of the sharded path."""
    hyb = get_smoke_config("zamba2-1.2b")            # Hg=8, G=1, di=128
    assert TP.decode_ssm_tp(hyb, 1)
    assert TP.decode_ssm_tp(hyb, 2)
    assert TP.decode_ssm_tp(hyb, 4)
    assert TP.decode_ssm_tp(hyb, 8)
    assert not TP.decode_ssm_tp(hyb, 3)              # Hg % tp != 0
    assert not TP.decode_ssm_tp(hyb, 16)             # wider than Hg
    # grouped B/C (ssm_groups > 1): the head shard would split groups
    assert not TP.decode_ssm_tp(
        dataclasses.replace(hyb, ssm_groups=2), 2)
    # the full config shards on the 16-wide production model axis
    from repro.configs import get_config
    assert TP.decode_ssm_tp(get_config("zamba2-1.2b"), 16)
    # attention archs without SSM dims never pass
    assert not TP.decode_ssm_tp(get_smoke_config("qwen2.5-32b"), 2)
    # the sharded param specs cover exactly the mamba param set
    from repro.models import ssm as SSM
    p, _ = SSM.mamba_init(jax.random.PRNGKey(0), hyb, jnp.float32)
    assert set(TP._mamba_param_specs()) == set(p)


def test_serve_manual_rules_pool_layout():
    """The fused-decode layout: pages over (pod, data) only, KV heads over
    model — serve_manual_rules + POOL_AXES_TP must resolve to exactly that."""
    from repro.serving import paged
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 4)))
    r = serve_manual_rules(mesh)
    spec = r.spec(paged.POOL_AXES_TP, (2, 8, 4, 8, 16))
    assert spec == P(None, "data", None, "model")
    # baseline serve rules keep pages over every axis and heads unsharded
    spec0 = serve_rules(mesh).spec(paged.POOL_AXES, (2, 8, 4, 8, 16))
    assert spec0 == P(None, ("data", "model"))


# ---------------------------------------------------------------------------
# compression (single-process pieces; the psum path runs in test_mesh).

def test_compress_leaf_error_feedback_identity():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(257,)),
                    jnp.float32)
    err = jnp.zeros_like(g)
    sent, err2 = COMP.compress_leaf(g, err)
    np.testing.assert_allclose(np.asarray(sent + err2), np.asarray(g),
                               atol=1e-6)


def test_compressed_bytes_counts_int8_payload():
    tree = {"a": jnp.zeros((10,)), "b": jnp.zeros((3, 4))}
    assert COMP.compressed_bytes(tree) == 10 + 4 + 12 + 4


# ---------------------------------------------------------------------------
# pipeline (single stage degenerates to sequential; S>1 runs in test_mesh).

def test_pipeline_single_stage_matches_sequential():
    mesh = jax.make_mesh((1,), ("pod",), devices=jax.devices()[:1])

    class Cfg:
        num_layers = 4

    ws = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8)) * 0.1

    def apply_range(w_stack, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, w_stack)
        return x

    fwd = PL.make_pipelined_forward(Cfg, mesh, apply_range, microbatches=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 8))
    np.testing.assert_allclose(np.asarray(jax.jit(fwd)(ws, x)),
                               np.asarray(apply_range(ws, x)),
                               atol=1e-6, rtol=1e-6)
    assert PL.bubble_fraction(4, 4) == pytest.approx(3 / 7)


def test_pipeline_rejects_bad_partition():
    mesh = jax.make_mesh((1,), ("pod",), devices=jax.devices()[:1])

    class Cfg:
        num_layers = 4

    fwd = PL.make_pipelined_forward(Cfg, mesh, lambda w, x: x,
                                    microbatches=3)
    with pytest.raises(ValueError):
        fwd(jnp.zeros((4, 2, 2)), jnp.zeros((4, 2)))   # 4 % 3 != 0


def test_elastic_host_loss_readmission():
    """dist/fault_tolerance.elastic_plan end-to-end: a host-group loss
    remeshes the survivors, the manifest reassigns the dead prefix ranges,
    and the per-shard schedulers re-admit every lost lane via recompute
    preemption — zero lost requests, table counters consistent."""
    import _multihost as MH
    from repro.dist import fault_tolerance as FT
    from repro.dist.table_shard import ShardManifest
    from repro.serving.sched import synthetic_workload

    cluster = MH.SimCluster(hosts=3, pages_per_shard=24, slots_per_shard=2,
                            page_size=4, max_len=16, megastep_k=4,
                            fail_on_abort=True)
    wl = synthetic_workload(9, vocab_size=64, max_len=16, seed=1,
                            prompt_len=(2, 4), max_new=(6, 10))
    cluster.router.submit_many(wl)
    for _ in range(3):
        cluster.run_round()
    lost_sid = cluster.spt.live_shards()[-1]
    victims_running = sum(
        1 for r in cluster.router.scheds[lost_sid].running())
    n_rehomed = cluster.lose_host(lost_sid)
    assert n_rehomed >= victims_running

    # (a) the surviving mesh and the reassigned manifest agree on the fleet
    new_man, shape, names = FT.elastic_table_plan(
        ShardManifest.balanced(3), lost_shard=lost_sid, model_parallel=16)
    assert len(new_man.live_shards()) == len(cluster.spt.live_shards()) == 2
    assert names == ("pod", "data", "model") and shape[0] == 2

    # (b) victims took the recompute-preemption transition
    rehomed = [r for sc in cluster.router.scheds.values()
               for r in list(sc.queue) + list(sc.running())
               if r.preemptions > 0]
    assert victims_running == 0 or rehomed

    # (c) the storm still drains with zero lost requests / zero aborts
    while not cluster.router.drained:
        assert cluster.rounds_run < 200
        cluster.run_round()
    cluster.verify()   # counters consistent (shadow census + per-shard)
    s = cluster.router.summary()
    assert int(s["completed"]) == int(s["submitted"]) == 9
    assert cluster.aborts == 0
