"""Simulated multi-process harness for the sharded page table.

One process plays N host groups on fake devices
(``--xla_force_host_platform_device_count``): each simulated host owns one
``TableShard`` (optionally pinned to its own fake device), its slice of
decode lanes, and its per-shard ``Scheduler`` (via ``PrefixRouter``).  The
harness drives the same round protocol as ``launch/serve.py`` — K virtual
decode steps, then plan/apply — against the routed allocator, with the
model replaced by the virtual clock (pages and admission behave exactly as
in serving; nothing about the table stack cares that logits are absent —
the same trade ``bench_throughput.sched_storm`` makes).

A **shadow page map** (global slot -> page key, plus per-sequence page
sets) is the harness's oracle: every allocation must claim an unclaimed
slot, every migration move must relocate exactly the shadow's entry, every
lookup must land on a slot whose shadow content is the looked-up key, and
per-shard live counters must equal the shadow's census.  This is how the
"no collision / counters consistent" acceptance checks are enforced.

Events injectable mid-storm:

* ``--grow-round R`` — force a LAZY resize of one shard at round R (on top
  of any grows the per-shard proactive controllers decide on their own);
  buckets then migrate under the storm via migrate-on-access + the
  per-round cursor sweep.
* ``--lose-round R`` — kill a host group at round R: its shard, pages and
  lanes vanish; ``dist.fault_tolerance.elastic_plan`` picks the surviving
  mesh, the manifest reassigns the prefix ranges, and the router re-homes
  every lost request through recompute preemption.

Underscore-prefixed so pytest does not collect it as a test module; the
pytest entry points live in ``tests/test_sharded_table.py`` and the CI
``shard-soak`` job runs the CLI directly::

    PYTHONPATH=src python tests/_multihost.py --hosts 4 --requests 48 \
        --overcommit 2.0 --lose-round 6 --grow-round 3 --fail-on-abort
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional

import numpy as np

import jax

from repro.serving import page_table as PT
from repro.serving.sched import Request, synthetic_workload
from repro.serving.sched.forecast import pages_held
from repro.serving.sched.router import PrefixRouter
from repro.serving.sharded_table import ShardedPageTable


class ShadowPages:
    """The oracle: who owns which global slot, maintained from the same
    alloc / move / free stream the pools would consume."""

    def __init__(self):
        self.slot_key: Dict[int, int] = {}          # global slot -> key
        self.seq_pages: Dict[int, Dict[int, int]] = {}  # seq -> {logical: slot}

    def alloc(self, seq: int, logical: int, slot: int) -> None:
        prev = self.slot_key.get(slot)
        assert prev is None or prev == seq * PT.MAX_LOGICAL_PAGES + logical, \
            f"slot {slot} double-claimed: {prev} vs ({seq},{logical})"
        self.slot_key[slot] = seq * PT.MAX_LOGICAL_PAGES + logical
        self.seq_pages.setdefault(seq, {})[logical] = slot

    def move(self, src: int, dst: int) -> None:
        key = self.slot_key.pop(src)
        assert dst not in self.slot_key, f"move onto live slot {dst}"
        self.slot_key[dst] = key
        seq, logical = divmod(key, PT.MAX_LOGICAL_PAGES)
        self.seq_pages[seq][logical] = dst

    def free_seq(self, seq: int) -> int:
        pages = self.seq_pages.pop(int(seq), {})
        for slot in pages.values():
            del self.slot_key[slot]
        return len(pages)

    def census(self) -> int:
        return len(self.slot_key)


class SimHost:
    """One simulated host group: a shard's decode lanes."""

    def __init__(self, sid: int, slots: int):
        self.sid = sid
        self.seq = np.zeros(slots, np.uint32)
        self.pos = np.zeros(slots, np.int64)
        self.stop = np.zeros(slots, np.int64)   # lane target length
        self.active = np.zeros(slots, bool)


class SimCluster:
    """N simulated hosts over one ShardedPageTable + PrefixRouter."""

    def __init__(self, *, hosts: int, pages_per_shard: int,
                 slots_per_shard: int, page_size: int = 4,
                 max_len: int = 32, megastep_k: int = 4,
                 strategy: str = "linear", safety_pages: int = 0,
                 place_on_devices: bool = False,
                 fail_on_abort: bool = False, verbose: bool = False,
                 tracer=None):
        max_pages = -(-max_len // page_size)
        self.spt = ShardedPageTable(hosts, pages_per_shard,
                                    strategy=strategy, page_size=page_size,
                                    max_pages=max_pages)
        self.router = PrefixRouter(self.spt, slots_per_shard=slots_per_shard,
                                   max_len=max_len, megastep_k=megastep_k,
                                   safety_pages=safety_pages,
                                   proactive=True, allow_grow=True)
        self.hosts: Dict[int, SimHost] = {
            sid: SimHost(sid, slots_per_shard)
            for sid in self.spt.live_shards()}
        self.K = megastep_k
        self.page_size = page_size
        self.shadow = ShadowPages()
        self.aborts = 0
        self.rounds_run = 0
        self.fail_on_abort = fail_on_abort
        self.verbose = verbose
        # span tracing (obs/trace.py): request spans come from the routed
        # schedulers; the harness adds per-round decode/migrate/health and
        # the final summary, all on the shared virtual clock
        self.tracer = tracer
        if tracer is not None:
            self.router.set_tracer(tracer)
        self._round_tokens: Dict[int, int] = {}
        self._round_pages: Dict[int, int] = {}
        self._devices = jax.devices() if place_on_devices else None
        if self._devices:
            self._place_all()

    # -- device placement (the "per-host table" part of the simulation) --

    def _place_all(self) -> None:
        for i, sid in enumerate(self.spt.live_shards()):
            self._place(sid, self._devices[i % len(self._devices)])

    def _place(self, sid: int, dev) -> None:
        st = self.spt._shards[sid]
        st.shard.table = jax.device_put(st.shard.table, dev)
        if st.shard.old is not None:
            st.shard.old = jax.device_put(st.shard.old, dev)

    def _clock(self) -> int:
        return self.router._clock()

    # -- lane views --------------------------------------------------------

    def _gather(self):
        """Concatenate every live host's lanes (order = live_shards)."""
        sids = list(self.hosts)
        seq = np.concatenate([self.hosts[s].seq for s in sids])
        pos = np.concatenate([self.hosts[s].pos for s in sids])
        stop = np.concatenate([self.hosts[s].stop for s in sids])
        act = np.concatenate([self.hosts[s].active for s in sids])
        return sids, seq, pos, stop, act

    def _scatter_pos(self, sids, pos) -> None:
        off = 0
        for s in sids:
            n = self.hosts[s].pos.size
            self.hosts[s].pos[:] = pos[off:off + n]
            off += n

    # -- the round ---------------------------------------------------------

    def decode_substeps(self) -> None:
        """K virtual decode steps: page-boundary allocations through the
        routed table; every write slot is checked against the shadow."""
        for _ in range(self.K):
            sids, seq, pos, stop, act = self._gather()
            # lanes at their stop idle until the planner reaps them — they
            # must not claim the page their (unreached) next position
            # would start (matches the forecaster's stop-clamped demand)
            run = act & (pos < stop)
            if run.any():
                ws, ab, moves = self.spt.alloc_step(seq, pos, active=run)
                for src, dst in moves:
                    self.shadow.move(src, dst)
                n_ab = int(ab.sum())
                if n_ab:
                    self.aborts += n_ab
                    if self.tracer is not None:
                        self.tracer.emit("abort", self._clock(),
                                         lanes=n_ab, grew_to=None)
                    if self.fail_on_abort:
                        raise AssertionError(
                            f"proactive-path ABORT on lanes "
                            f"{np.nonzero(ab)[0].tolist()} at round "
                            f"{self.rounds_run}")
                live = run & ~ab
                assert (ws[live] >= 0).all(), "live lane denied a write slot"
                uniq = np.unique(ws[live])
                assert uniq.size == int(live.sum()), \
                    "two lanes share a physical page"
                boundary = live & (pos % self.page_size == 0)
                for i in np.nonzero(boundary)[0]:
                    self.shadow.alloc(int(seq[i]),
                                      int(pos[i]) // self.page_size,
                                      int(ws[i]))
                if self.tracer is not None:
                    # per-shard decode attribution for the round's spans
                    off = 0
                    for s in sids:
                        n = self.hosts[s].pos.size
                        sl = slice(off, off + n)
                        self._round_tokens[s] = (self._round_tokens.get(s, 0)
                                                 + int(live[sl].sum()))
                        self._round_pages[s] = (self._round_pages.get(s, 0)
                                                + int(boundary[sl].sum()))
                        off += n
                pos = pos + live.astype(np.int64)   # aborted lanes freeze
                self._scatter_pos(sids, pos)
            # migration makes progress every substep, like a background
            # helper thread would
            for src, dst in self.spt.service_migration():
                self.shadow.move(src, dst)

    def plan_and_apply(self, mig0: Optional[Dict[int, int]] = None,
                       win0: Optional[set] = None) -> None:
        self.router.advance(self.K)
        # first sampled (non-forced) token: the lane's position moved past
        # its recompute-prefill length — what TTFT measures
        for sid, sc in self.router.scheds.items():
            host = self.hosts[sid]
            for s, r in enumerate(sc.lanes):
                if (r is not None and r.first_token_at is None
                        and host.pos[s] > getattr(r, "_prefill_len", 0)):
                    r.first_token_at = sc.clock
                    if self.tracer is not None:
                        self.tracer.emit("first_token", sc.clock,
                                         req=r.req_id, shard=sid)
        if self.tracer is not None:
            # per-round spans, emitted BEFORE plan_round so the line order
            # keeps this round's inserts outside any window plan_round is
            # about to open (trace invariant 2 leans on that ordering)
            clock = self._clock()
            for sid in self.hosts:
                shard = self.spt.shard(sid)
                reqs = [r.req_id for r in self.router.scheds[sid].lanes
                        if r is not None]
                self.tracer.emit("decode", clock, shard=sid, reqs=reqs,
                                 tokens=self._round_tokens.get(sid, 0),
                                 pages=self._round_pages.get(sid, 0))
                if win0 and sid in win0:
                    # every open-window round reports progress, even 0 moves
                    moved = shard.migrated - (mig0 or {}).get(sid, 0)
                    self.tracer.emit("migrate", clock, shard=sid,
                                     moved=moved)
                    if not shard.migrating:
                        self.tracer.emit("migrate_done", clock, shard=sid,
                                         total=shard.migrated)
                h = self.spt.health(sid)
                self.tracer.emit("shard_health", clock, shard=sid, **h)
        positions = {sid: self.hosts[sid].pos for sid in self.hosts}
        plans = self.router.plan_round(positions)
        for sid, plan in plans.items():
            host = self.hosts[sid]
            evict = plan.evict_slots
            if evict:
                idx = np.asarray(evict)
                moves = self.spt.free_sequences(host.seq[idx], host.pos[idx],
                                                active=host.active[idx])
                for src, dst in moves:
                    self.shadow.move(src, dst)
                for s in evict:
                    if host.active[s]:
                        self.shadow.free_seq(int(host.seq[s]))
                    host.active[s] = False
            for slot, req in plan.admissions:
                host.seq[slot] = self.router.seq_of[req.req_id]
                host.pos[slot] = 0
                host.stop[slot] = self.router.scheds[sid].stop_of(req)
                host.active[slot] = True
        self.router.end_round()

    def run_round(self) -> None:
        live0 = set(self.spt.live_shards())
        # migration-window membership + move counts at round start: the
        # round's migrate events report the delta over its substeps
        mig0 = {sid: self.spt.shard(sid).migrated for sid in live0}
        win0 = {sid for sid in live0 if self.spt.shard(sid).migrating}
        self._round_tokens = {}
        self._round_pages = {}
        self.decode_substeps()
        self.plan_and_apply(mig0=mig0, win0=win0)
        self.rounds_run += 1

    # -- events ------------------------------------------------------------

    def force_grow(self, sid: Optional[int] = None, factor: int = 2) -> int:
        """Begin a lazy resize of one stable shard (first live by
        default)."""
        cands = [s for s in self.spt.live_shards()
                 if not self.spt.shard(s).migrating]
        if not cands:
            return -1
        sid = cands[0] if sid is None or sid not in cands else sid
        old_pages = self.spt.headroom(sid).n_pages
        self.spt.grow_shard(sid, self.spt.shard(sid).n_cells() * factor)
        self.router.scheds[sid].n_pages = self.spt.headroom(sid).n_pages
        if self.tracer is not None:
            # injected lazy resize opens the same frozen-old-table window
            # a controller-decided grow would
            self.tracer.emit("grow", self._clock(), shard=sid,
                             n_pages_old=old_pages,
                             n_pages_new=self.spt.headroom(sid).n_pages)
        if self._devices:
            self._place_all()
        return sid

    def lose_host(self, sid: Optional[int] = None) -> int:
        """Kill a host group: shard + pages + lanes vanish; the router
        re-homes its requests (recompute preemption)."""
        live = self.spt.live_shards()
        if len(live) < 2:
            raise RuntimeError("cannot lose the last host")
        sid = live[-1] if sid is None else sid
        host = self.hosts.pop(sid)
        for s in np.nonzero(host.active)[0]:
            self.shadow.free_seq(int(host.seq[s]))  # pages died with host
        victims = self.router.lose_host(sid)
        if self.verbose:
            print(f"  [round {self.rounds_run}] lost host {sid}: "
                  f"{len(victims)} requests re-homed to "
                  f"{self.spt.manifest.live_shards()}")
        return len(victims)

    # -- verification ------------------------------------------------------

    def verify(self) -> None:
        """The acceptance checks: shadow vs table, per-shard counters,
        lookup answers."""
        # census: every table key is shadow-owned and vice versa
        assert self.spt.total_live_pages() == self.shadow.census(), \
            (self.spt.counters(), self.shadow.census())
        # per-shard counters == lane arithmetic
        for sid, host in self.hosts.items():
            held = sum(pages_held(int(p), self.page_size)
                       for p, a in zip(host.pos, host.active) if a)
            live = self.spt.shard(sid).live_pages()
            assert live == held, (sid, live, held, self.spt.counters())
        # routed lookup answers == shadow content
        sids, seq, pos, stop, act = self._gather()
        if not act.any():
            return
        bt = self.spt.lookup_pages(seq[act], pos[act])
        for row, (s, p) in enumerate(zip(seq[act], pos[act])):
            held = pages_held(int(p), self.page_size)
            for logical in range(bt.shape[1]):
                g = int(bt[row, logical])
                if logical < held:
                    key = int(s) * PT.MAX_LOGICAL_PAGES + logical
                    assert g >= 0 and self.shadow.slot_key[g] == key, \
                        (int(s), logical, g)
                else:
                    assert g == -1

    # -- the storm ---------------------------------------------------------

    def run_storm(self, requests: List[Request], *, max_rounds: int = 400,
                  grow_round: Optional[int] = None,
                  lose_round: Optional[int] = None,
                  verify_every: int = 2) -> Dict[str, float]:
        self.router.submit_many(requests)
        while not self.router.drained:
            if self.rounds_run >= max_rounds:
                raise AssertionError(
                    f"storm did not drain in {max_rounds} rounds: "
                    f"{self.router.summary()}")
            if grow_round is not None and self.rounds_run == grow_round:
                self.force_grow()
            if lose_round is not None and self.rounds_run == lose_round:
                self.lose_host()
            self.run_round()
            if self.rounds_run % verify_every == 0:
                self.verify()
        self.verify()
        s = self.router.summary()
        s["rounds"] = self.rounds_run
        s["aborts_observed"] = self.aborts
        s["live_shards"] = len(self.spt.live_shards())
        if self.tracer is not None:
            # the sim's aborts are cluster-observed (alloc_step), not
            # scheduler-reported; the summary carries the observed count so
            # the trace checker reconciles abort events against it
            fields = {k: (float(v) if isinstance(v, float) else int(v))
                      for k, v in s.items()}
            fields["aborts"] = self.aborts
            self.tracer.emit("summary", self._clock(), **fields)
        return s


def elastic_remesh_after_loss(n_hosts: int, lost: int,
                              chips_per_host: int = 256):
    """What ``dist.fault_tolerance.elastic_plan`` picks for the surviving
    fleet — the harness asserts the survivor mesh matches the shard count
    the routing layer keeps serving with."""
    from repro.dist.fault_tolerance import elastic_plan
    return elastic_plan((n_hosts - lost) * chips_per_host, model_parallel=16)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--pages-per-shard", type=int, default=48)
    ap.add_argument("--slots-per-shard", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--megastep-k", type=int, default=4)
    ap.add_argument("--strategy", default="linear")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--overcommit", type=float, default=2.0,
                    help="demand / capacity ratio of the storm (>=1 means "
                         "the pool cannot hold every request at once)")
    ap.add_argument("--grow-round", type=int, default=None)
    ap.add_argument("--lose-round", type=int, default=None)
    ap.add_argument("--max-rounds", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-on-abort", action="store_true")
    ap.add_argument("--place-on-devices", action="store_true",
                    help="pin each shard's tables to its own jax device")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a request-span / table-health JSONL trace "
                         "(obs/trace.py; check with tools/trace_report.py)")
    args = ap.parse_args(argv)

    if args.place_on_devices and len(jax.devices()) < 2:
        print(f"warning: --place-on-devices with "
              f"{len(jax.devices())} device(s); set XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8 for the real leg")

    # size the storm to the requested overcommit: page demand of the whole
    # workload vs TOTAL pool capacity (so per-shard pressure is ~overcommit
    # regardless of host count)
    cap = args.hosts * args.pages_per_shard
    max_pages = -(-args.max_len // args.page_size)
    per_req = max_pages  # worst case: a request at max_len
    n_req = max(args.requests, int(args.overcommit * cap / per_req))
    wl = synthetic_workload(n_req, vocab_size=256, max_len=args.max_len,
                            seed=args.seed, prompt_len=(2, 5),
                            max_new=(args.max_len - 8, args.max_len - 4))

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(args.trace)

    cluster = SimCluster(
        hosts=args.hosts, pages_per_shard=args.pages_per_shard,
        slots_per_shard=args.slots_per_shard, page_size=args.page_size,
        max_len=args.max_len, megastep_k=args.megastep_k,
        strategy=args.strategy, fail_on_abort=args.fail_on_abort,
        place_on_devices=args.place_on_devices, verbose=True,
        tracer=tracer)

    print(f"shard-soak: hosts={args.hosts} pages/shard="
          f"{args.pages_per_shard} requests={len(wl)} "
          f"(overcommit~{args.overcommit}) K={args.megastep_k} "
          f"strategy={args.strategy} devices={len(jax.devices())}")
    s = cluster.run_storm(wl, max_rounds=args.max_rounds,
                          grow_round=args.grow_round,
                          lose_round=args.lose_round)

    if tracer is not None:
        tracer.close()
        print(f"  trace: {tracer.path} ({tracer.n_events} events)")

    if args.lose_round is not None:
        shape = elastic_remesh_after_loss(args.hosts, 1)
        print(f"  elastic_plan survivor mesh: {shape}")

    print(f"  drained in {int(s['rounds'])} rounds: completed="
          f"{int(s['completed'])}/{int(s['submitted'])} "
          f"rehomed={int(s['rehomed'])} preempt="
          f"{int(s['preemptive_evictions'])} grows={int(s['pool_grows'])} "
          f"aborts={int(s['aborts_observed'])} "
          f"avoided={int(s['aborts_avoided'])} "
          f"ttft_p99={s['ttft_p99']:.0f} steps")

    ok = (int(s["completed"]) == int(s["submitted"]))
    if not ok:
        print("FAIL: lost requests", file=sys.stderr)
    if args.fail_on_abort and cluster.aborts:
        print(f"FAIL: {cluster.aborts} proactive-path aborts",
              file=sys.stderr)
        ok = False
    print("shard-soak OK" if ok else "shard-soak FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
