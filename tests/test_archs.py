"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward and one train step on CPU; output shapes and
finiteness asserted.  The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import SHAPES, cell_applicable, input_specs
from repro.models.registry import get_model
from repro.training import data as D
from repro.training.train_step import init_state, make_train_step

ALL_ARCHS = sorted(ARCH_IDS)


def _extra_inputs(cfg, B, S, key):
    kw = {}
    if cfg.family == "encdec":
        kw["src_embeds"] = jax.random.normal(
            key, (B, max(S // 8, 1), cfg.d_model), cfg.activation_dtype())
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            key, (B, S // 4, cfg.d_model), cfg.activation_dtype())
        kw["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    return kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params, axes = model.init(cfg, key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, aux = model.forward(cfg, params, tokens,
                                **_extra_inputs(cfg, B, S, key))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    state, _ = init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    for i in range(2):
        batch = D.synth_batch(cfg, batch=2, seq_len=32, step=i)
        state, metrics = step(state, batch)
        assert jnp.isfinite(metrics["loss"])
        assert jnp.isfinite(metrics["grad_norm"])
    assert int(state.step) == 2


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_exact_numbers(arch):
    """The full config must carry the exact published numbers."""
    cfg = get_config(arch)
    published = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == published, (arch, got, published)
    # family extras
    if arch == "granite-moe-1b-a400m":
        assert (cfg.num_experts, cfg.experts_per_token) == (32, 8)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 8)
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64
    if arch == "mamba2-2.7b":
        assert cfg.ssm_state == 128
    if arch == "gemma3-12b":
        assert (cfg.pattern_local, cfg.local_window) == (5, 1024)
    if arch == "qwen2-vl-7b":
        assert cfg.mrope_sections == (16, 24, 24)


def test_long_500k_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §6)."""
    runnable = {a for a in ALL_ARCHS
                if cell_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runnable == {"zamba2-1.2b", "mamba2-2.7b", "gemma3-12b"}


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_defined(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    specs = input_specs(cfg, sh)
    assert "tokens" in specs
    for k, sds in specs.items():
        assert all(d > 0 for d in sds.shape), (k, sds.shape)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_sane(arch):
    """Analytic N lands near the advertised size class."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "zamba2-1.2b": 1.2e9, "qwen1.5-32b": 32e9, "qwen2.5-32b": 32e9,
        "gemma3-12b": 12e9, "codeqwen1.5-7b": 7e9,
        "seamless-m4t-large-v2": 2.3e9, "granite-moe-1b-a400m": 1.3e9,
        "qwen3-moe-235b-a22b": 235e9, "mamba2-2.7b": 2.7e9,
        "qwen2-vl-7b": 7e9,
    }[arch]
    assert 0.4 * expected < n < 1.9 * expected, (arch, n, expected)
