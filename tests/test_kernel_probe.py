"""Probe-lookup kernel vs jnp oracle (interpret mode), shape/load sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched as BT
from repro.kernels.probe import probe_lookup, probe_lookup_ref, resolved_fraction


def build_table(m, n_keys, seed, key_range=None, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    ht = BT.create(m, seed=seed)
    key_range = key_range or 10 * m
    keys = rng.choice(key_range, size=n_keys, replace=False).astype(np.uint32)
    ht, ret = BT.insert_batch(ht, jnp.asarray(keys))
    assert not np.any(np.asarray(ret) == 2)
    return ht, keys


@pytest.mark.parametrize("m,TB,KT", [(512, 256, 128), (4096, 2048, 128),
                                     (4096, 256, 128), (2048, 1024, 64)])
@pytest.mark.parametrize("load", [0.3, 0.7, 0.9])
def test_kernel_matches_ref(m, TB, KT, load):
    ht, keys = build_table(m, int(m * load), seed=7, rng_seed=m + int(load * 10))
    rng = np.random.default_rng(1)
    B = 512
    # half present, half absent
    qk = np.concatenate([
        rng.choice(keys, size=B // 2),
        rng.integers(10 * m, 20 * m, size=B // 2),
    ]).astype(np.uint32)
    rng.shuffle(qk)
    qk = jnp.asarray(qk)
    f_ref, s_ref = probe_lookup_ref(ht.table, qk, int(ht.seed))
    f_k, s_k = probe_lookup(ht, qk, TB=TB, KT=KT, interpret=True)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_kernel_with_tombstones_and_wrap():
    """Runs crossing the m boundary and tombstones in runs."""
    m, TB = 512, 256
    ht, keys = build_table(m, 400, seed=3, rng_seed=42)
    # delete a third of them -> tombstones inside runs
    ht, _ = BT.delete_batch(ht, jnp.asarray(keys[::3].copy()))
    rng = np.random.default_rng(2)
    qk = jnp.asarray(np.concatenate([keys, rng.integers(10 * m, 20 * m,
                                                        size=256)])
                     .astype(np.uint32))
    f_ref, s_ref = probe_lookup_ref(ht.table, qk, int(ht.seed))
    f_k, s_k = probe_lookup(ht, qk, TB=TB, interpret=True)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_small_batches_and_padding():
    m, TB = 1024, 256
    ht, keys = build_table(m, 300, seed=9, rng_seed=5)
    for B in [1, 3, 64, 130]:
        qk = jnp.asarray(keys[:B].astype(np.uint32))
        f_ref, s_ref = probe_lookup_ref(ht.table, qk, int(ht.seed))
        f_k, s_k = probe_lookup(ht, qk, TB=TB, interpret=True)
        np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))


def test_unresolved_runs_fall_back_to_oracle():
    """Adversarial load: a single giant probe run (every key hashed into one
    narrow band) extends far past the kernel's two-block resident window, so
    keys deep in the run can see neither their cell nor an EMPTY — they must
    be reported unresolved and served by the jnp oracle with identical
    results (present AND absent queries)."""
    m, TB = 512, 256
    ht = BT.create(m, seed=5)
    rng = np.random.default_rng(12)
    cand = rng.choice(1 << 27, size=1 << 17, replace=False).astype(np.uint32)
    hv = np.asarray(BT._hash(ht, jnp.asarray(cand)))
    band = cand[hv < 64]
    assert len(band) >= 428, len(band)
    clustered = band[:300]          # run spans ~300 cells from slot < 64
    ht, ret = BT.insert_batch(ht, jnp.asarray(clustered))
    assert not np.any(np.asarray(ret) == 2)

    absent = band[300:428]          # same band, never inserted
    qk = jnp.asarray(np.concatenate([clustered, absent]))
    frac = float(resolved_fraction(ht, qk, TB=TB, interpret=True))
    assert frac < 1.0, "run never left the resident window — not adversarial"
    assert frac > 0.0, "even run heads unresolved — kernel fast path broken"

    f_k, s_k = probe_lookup(ht, qk, TB=TB, interpret=True)
    f_ref, s_ref = BT.find_batch(ht, qk)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_ref))
    assert np.asarray(f_k)[:300].all()          # every inserted key found
    assert not np.asarray(f_k)[300:].any()      # absent stay absent


def test_fast_path_coverage():
    """At moderate load the kernel should resolve nearly all keys itself."""
    m = 8192
    ht, keys = build_table(m, int(0.6 * m), seed=11, rng_seed=8)
    rng = np.random.default_rng(3)
    qk = jnp.asarray(rng.choice(keys, size=1024).astype(np.uint32))
    frac = float(resolved_fraction(ht, qk, TB=2048, interpret=True))
    assert frac > 0.95, frac
