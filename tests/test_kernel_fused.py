"""Fused probe+paged-attention decode kernel (kernels/fused_decode).

Kernel level: the fused one-dispatch kernel must be BITWISE identical to
the two-dispatch baseline (materialized slots view -> paged-attention
kernel) it replaces — dense MHA / GQA / MQA, f32 / bf16, int8+scales,
and the unnormalized (o, m, l) partials contract.

Engine level: a serve step with ``cfg.fused_kernel=True`` must match the
two-dispatch step — gspmd AND the fully-manual shard_map region — and the
adversarial probe-run construction must exercise the probe kernel's
in-graph oracle fallback through ``rebuild_block_table(use_kernel=True)``
with bitwise-identical rows.

The whole file runs in interpret mode and under EITHER 1 or 8 fake
devices (CI kernels-interpret matrix): mesh-dependent tests size their
mesh from ``jax.device_count()``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import batched as BT
from repro.dist.sharding import serve_manual_rules
from repro.kernels import stats as KS
from repro.kernels.fused_decode import (block_table_slots_ref,
                                        fused_decode_ref,
                                        fused_paged_attention,
                                        merge_fused_partials)
from repro.kernels.probe import probe_lookup, resolved_fraction
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving import page_table as PT


# ---------------------------------------------------------------------------
# Kernel-level bitwise parity.

def make_inputs(B, QH, KH, D, NP, PS, MP, dtype, seed=0, holes=False):
    """Random pools + a raw incremental-style block table: each sequence at
    position pos[b] owns distinct physical pages for logicals 0..pos//PS
    (optionally with stale entries past the horizon, as a real incremental
    cache can briefly hold — the kernel must mask them by position)."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, QH, D)).astype(np.float32)
    k = rng.standard_normal((NP, PS, KH, D)).astype(np.float32)
    v = rng.standard_normal((NP, PS, KH, D)).astype(np.float32)
    pos = rng.integers(0, MP * PS, size=B).astype(np.int32)
    perm = rng.permutation(NP)
    bt = np.full((B, MP), -1, np.int32)
    nxt = 0
    for b in range(B):
        last = pos[b] // PS
        for p in range(MP):
            if p <= last or (holes and rng.random() < 0.5):
                bt[b, p] = perm[nxt % NP]
                nxt += 1
    return (jnp.asarray(q, dtype), jnp.asarray(k, dtype),
            jnp.asarray(v, dtype), jnp.asarray(bt), jnp.asarray(pos))


SHAPES = [
    (2, 4, 4, 32, 16, 8, 4),     # dense MHA
    (2, 8, 2, 32, 16, 8, 4),     # GQA G=4
    (3, 4, 1, 16, 32, 4, 8),     # MQA, small pages
    (1, 4, 2, 64, 8, 16, 2),     # single lane, wide head
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_bitwise_vs_two_dispatch(shape, dtype):
    B, QH, KH, D, NP, PS, MP = shape
    q, k, v, bt, pos = make_inputs(B, QH, KH, D, NP, PS, MP, dtype,
                                   seed=sum(shape))
    out = fused_paged_attention(q, k, v, bt, pos, interpret=True)
    ref = fused_decode_ref(q, k, v, bt, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_bitwise_with_stale_rows():
    """Raw-table entries past the live horizon (and -1 holes) must be
    position-masked in-kernel exactly like the slots view masks them."""
    q, k, v, bt, pos = make_inputs(4, 4, 4, 32, 64, 8, 6, jnp.bfloat16,
                                   seed=3, holes=True)
    out = fused_paged_attention(q, k, v, bt, pos, interpret=True)
    ref = fused_decode_ref(q, k, v, bt, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_int8_scales_bitwise():
    B, QH, KH, D, NP, PS, MP = 2, 8, 2, 32, 16, 8, 4
    q, k, v, bt, pos = make_inputs(B, QH, KH, D, NP, PS, MP, jnp.float32,
                                   seed=11)
    rng = np.random.default_rng(7)
    k8 = jnp.asarray(rng.integers(-127, 128, k.shape), jnp.int8)
    v8 = jnp.asarray(rng.integers(-127, 128, v.shape), jnp.int8)
    scales = (jnp.asarray(rng.uniform(0.01, 0.2, (NP, PS, KH)),
                          jnp.bfloat16),
              jnp.asarray(rng.uniform(0.01, 0.2, (NP, PS, KH)),
                          jnp.bfloat16))
    out = fused_paged_attention(q.astype(jnp.bfloat16), k8, v8, bt, pos,
                                scales=scales, interpret=True)
    ref = fused_decode_ref(q.astype(jnp.bfloat16), k8, v8, bt, pos,
                           scales=scales, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_partials_contract():
    """partials=True returns the unnormalized per-chip (o, m, l) triple:
    merging it must reproduce the normalized single-chip output."""
    B, QH, KH, D, NP, PS, MP = 2, 4, 2, 32, 16, 8, 4
    q, k, v, bt, pos = make_inputs(B, QH, KH, D, NP, PS, MP, jnp.float32,
                                   seed=21)
    o, m, l = fused_paged_attention(q, k, v, bt, pos, partials=True,
                                    interpret=True)
    assert o.shape == (B, KH, QH // KH, D) and o.dtype == jnp.float32
    assert m.shape == l.shape == (B, KH, QH // KH)
    merged = merge_fused_partials(o, m, l).reshape(B, QH, D)
    full = fused_paged_attention(q, k, v, bt, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


def test_slots_ref_matches_serving_view():
    """The kernel package's local duplicate of the slots math must equal
    serving/page_table.block_table_slots (drift here silently changes what
    'two-dispatch baseline' means)."""
    rng = np.random.default_rng(5)
    bt = jnp.asarray(rng.integers(-1, 64, (8, 16)), jnp.int32)
    pos = jnp.asarray(rng.integers(0, 16 * 8, 8), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(block_table_slots_ref(bt, pos, page_size=8)),
        np.asarray(PT.PageTable.block_table_slots(bt, pos,
                                                  page_size=8)))


def test_fused_byte_accounting():
    """Eager fused dispatch accounts bytes structurally: the raw table read
    (B·MP·4, no slot round trip) + only the LIVE fetched pages."""
    B, QH, KH, D, NP, PS, MP = 2, 4, 4, 32, 16, 8, 4
    q, k, v, bt, pos = make_inputs(B, QH, KH, D, NP, PS, MP, jnp.bfloat16,
                                   seed=2)
    live = np.arange(MP)[None, :] * PS <= np.asarray(pos)[:, None]
    fetched = int(np.sum(live & (np.asarray(bt) >= 0)))
    with KS.kernel_stats_scope() as st:
        fused_paged_attention(q, k, v, bt, pos, interpret=True)
        got = dict(st)           # read BEFORE exit: the scope restores
    assert got["probe_bytes"] == B * MP * 4
    assert got["attn_bytes"] == fetched * KH * PS * D * 4   # bf16 k+v


# ---------------------------------------------------------------------------
# Engine-level parity (gspmd + manual), 1 or 8 fake devices.

def _decode_parity(cfg0, rules, T=8, atol=1e-4):
    model = get_model(cfg0)
    params, _ = model.init(cfg0, jax.random.PRNGKey(0))
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg0.vocab_size)

    def run(cfg):
        state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4,
                                        rules=rules)
        step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4,
                                          rules=rules))
        outs = []
        for t in range(T):
            pos = jnp.full((B,), t, jnp.int32)
            args = (params, state, toks[:, t:t + 1], pos)
            if cfg.family == "vlm":
                args += (jnp.full((3, B, 1), t, jnp.int32),)
            lg, state = step(*args)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    fused_cfg = dataclasses.replace(cfg0, fused_kernel=True)
    assert EG._fused_kernel_ok(fused_cfg, rules), \
        EG._fused_kernel_reason(fused_cfg, rules)
    np.testing.assert_allclose(run(fused_cfg), run(cfg0), atol=atol,
                               rtol=1e-5)


@pytest.mark.parametrize("arch,over", [
    ("qwen2.5-32b", {}),                            # dense GQA
    ("granite-moe-1b-a400m", {}),                   # MoE
    ("gemma3-12b", {}),                             # local:global pattern
    ("qwen2.5-32b", {"kv_cache_dtype": "int8"}),    # quantized KV pool
    ("qwen2-vl-7b", {}),                            # vlm (mrope)
])
def test_engine_fused_matches_two_dispatch_gspmd(arch, over):
    cfg = dataclasses.replace(get_smoke_config(arch), **over)
    _decode_parity(cfg, rules=None)


def _manual_mesh():
    n = jax.device_count()
    shape = (2, n // 2) if n >= 2 else (1, 1)
    return jax.make_mesh(shape, ("data", "model"),
                         devices=jax.devices()[:shape[0] * shape[1]])


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "zamba2-1.2b"])
def test_engine_fused_matches_two_dispatch_manual(arch):
    """The fused kernel inside the fully-manual shard_map region (per-chip
    raw-block-table walk + lse merge over the page axes) vs the
    compact+attend two-dispatch region — whatever mesh the CI leg's device
    count allows (1x1 or 2x4)."""
    cfg = dataclasses.replace(get_smoke_config(arch), tp_impl="manual")
    rules = serve_manual_rules(_manual_mesh())
    assert EG._manual_decode_ok(cfg, rules)
    _decode_parity(cfg, rules=rules)


def test_fused_gate_reasons_never_silent():
    """Every non-fused outcome has a reason string; the families that
    cannot take the kernel are named, not dropped."""
    dense = get_smoke_config("qwen2.5-32b")
    assert "off" in EG._fused_kernel_reason(dense, None)
    on = dataclasses.replace(dense, fused_kernel=True)
    assert EG._fused_kernel_reason(on, None) is None
    ssm = dataclasses.replace(get_smoke_config("mamba2-2.7b"),
                              fused_kernel=True)
    assert "SSM" in EG._fused_kernel_reason(ssm, None)
    encdec = dataclasses.replace(get_smoke_config("seamless-m4t-large-v2"),
                                 fused_kernel=True)
    assert "cross-attention" in EG._fused_kernel_reason(encdec, None)


# ---------------------------------------------------------------------------
# Satellite: adversarial probe-run fallback through the rebuild path.

def test_adversarial_rebuild_falls_back_bitwise():
    """A single giant probe run (filler keys clustered into one narrow hash
    band) extends past the probe kernel's resident window, so page keys
    deep in the run are UNRESOLVED by the fast path and must be served by
    the in-graph oracle — ``rebuild_block_table(use_kernel=True)`` must be
    bitwise-identical to the oracle rebuild, and a decode step from either
    rebuilt state must produce identical logits (gspmd and manual)."""
    m, TB, MP = 512, 256, 8
    table = BT.create(m, seed=5)
    rng = np.random.default_rng(12)

    # filler run: arbitrary uint32 keys whose hash lands in cells < 64
    cand = rng.choice(1 << 27, size=1 << 17, replace=False).astype(np.uint32)
    hv = np.asarray(BT._hash(table, jnp.asarray(cand)))
    filler = cand[hv < 64][:280]
    table, ret = BT.insert_batch(table, jnp.asarray(filler))
    assert not np.any(np.asarray(ret) == 2)

    # sequences with at least one page key hashing INTO the band — that
    # key's probe starts inside the ~280-cell run and must walk past the
    # kernel's resident window to its (late-inserted) cell
    seqs = []
    for s in range(4096):
        keys = PT.page_key(jnp.uint32(s), jnp.arange(MP, dtype=jnp.uint32))
        kh = np.asarray(BT._hash(table, keys))
        if (kh < 64).any():
            seqs.append(s)
        if len(seqs) == 8:
            break
    assert len(seqs) == 8, "rejection sampling found too few band seqs"
    seq_ids = jnp.asarray(seqs, jnp.uint32)
    page_keys = PT.page_key(seq_ids[:, None],
                            jnp.arange(MP, dtype=jnp.uint32)[None, :])
    table, ret = BT.insert_batch(table, page_keys.reshape(-1))
    assert not np.any(np.asarray(ret) == 2)

    # the construction is genuinely adversarial: the kernel fast path must
    # resolve SOME of the probed keys but not all of them
    frac = float(resolved_fraction(table, page_keys.reshape(-1), TB=TB,
                                   interpret=True))
    assert 0.0 < frac < 1.0, frac

    f_k, s_k = probe_lookup(table, page_keys.reshape(-1), TB=TB,
                            interpret=True)
    f_o, s_o = BT.find_batch(table, page_keys.reshape(-1))
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_o))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_o))

    pt = PT.for_strategy("linear")
    bt_k = pt.rebuild_block_table(table, seq_ids, MP, use_kernel=True)
    bt_o = pt.rebuild_block_table(table, seq_ids, MP, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(bt_k), np.asarray(bt_o))


@pytest.mark.parametrize("mode", ["gspmd", "manual"])
def test_rebuild_use_kernel_identical_decode(mode):
    """Engine rebuild with the probe kernel vs the oracle: the rebuilt
    states are bitwise-identical, so the next decode step is too — checked
    end-to-end on both serve paths."""
    cfg = get_smoke_config("qwen1.5-32b")
    rules = None
    if mode == "manual":
        cfg = dataclasses.replace(cfg, tp_impl="manual")
        rules = serve_manual_rules(_manual_mesh())
        assert EG._manual_decode_ok(cfg, rules)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B = 2
    state, _ = EG.make_decode_state(cfg, B, S_max=32, page_size=4,
                                    rules=rules)
    step = jax.jit(EG.make_serve_step(cfg, S_max=32, page_size=4,
                                      rules=rules))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 6), 0,
                              cfg.vocab_size)
    for t in range(6):
        pos = jnp.full((B,), t, jnp.int32)
        _, state = step(params, state, toks[:, t:t + 1], pos)

    st_k = EG.rebuild_page_table(dict(state), use_kernel=True)
    st_o = EG.rebuild_page_table(dict(state), use_kernel=False)
    np.testing.assert_array_equal(np.asarray(st_k["block_table"]),
                                  np.asarray(st_o["block_table"]))
    pos = jnp.full((B,), 6, jnp.int32)
    lg_k, _ = step(params, st_k, toks[:, :1], pos)
    lg_o, _ = step(params, st_o, toks[:, :1], pos)
    np.testing.assert_array_equal(np.asarray(lg_k), np.asarray(lg_o))
