"""Theorem 21 reproduction: expected amortized step complexity O(x² + c).

Two sweeps on the faithful simulator (exact Algorithms 1-6 event machine):
  (a) load factor: insert-only batches filling to (1-1/x)m for several x,
      no concurrent same-key inserts -> mean steps/op vs Knuth's x² curve.
  (b) contention: c-bounded fixed workloads at fixed load -> mean steps/op
      vs c (expect ~linear additive growth).
Both LL/SC and CAS variants (Thm 21 covers both).
"""
from __future__ import annotations

import numpy as np

from repro.core import schedulers as SCH
from repro.core import simulator as SIM
from repro.core.simulator import Workload
from repro.core.spec import OP_INSERT, OP_NONE

SCH.Workload = Workload
SCH.OP_INSERT = OP_INSERT
from repro.core.spec import OP_DELETE as _OPD, OP_LOOKUP as _OPL
SCH.OP_DELETE = _OPD
SCH.OP_LOOKUP = _OPL


def _mean_steps(state, wl, only_op=None) -> float:
    op = np.asarray(wl.op)
    steps = np.asarray(state.steps)
    res = np.asarray(state.results)
    mask = (op != OP_NONE) & (res != -1)       # completed ops only
    if only_op is not None:
        mask &= op == only_op
    return float(steps[mask].mean()) if mask.any() else float("nan")


def sweep_load(mode: str, m: int = 256, P: int = 8, seed: int = 0,
               xs=(1.5, 2.0, 3.0, 4.0)) -> list:
    rng = np.random.default_rng(seed)
    rows = []
    for x in xs:
        n_ins = int((1 - 1 / x) * m)
        K = -(-n_ins // P)
        wl = SCH.insert_only_distinct(P, K)
        # random keys (sequential keys + multiply-shift = unrealistically
        # uniform spread; Knuth's model assumes random hashing)
        wl.key[:, :] = rng.choice(2 ** 27, size=(P, K),
                                  replace=False).astype(np.uint32)
        # trim overfill
        wl.op[:, :][np.arange(P * K).reshape(P, K) >= n_ins] = OP_NONE
        T = 400 * P * K
        sched = SCH.uniform_schedule(rng, P, T)
        st = SIM.simulate(wl, m, sched, mode=mode)
        done = (np.asarray(st.results) != -1) | (np.asarray(wl.op) == OP_NONE)
        assert done.all(), f"x={x}: {int((~done).sum())} ops unfinished"
        rows.append({"x": x, "load": 1 - 1 / x,
                     "mean_steps": _mean_steps(st, wl),
                     "knuth_x2": 0.5 * (1 + x * x)})
    return rows


def sweep_contention(mode: str, m: int = 64, K: int = 12,
                     seed: int = 1, cs=(1, 2, 4, 6)) -> list:
    """Direct point-contention setup: ONE key; process 0 alternates
    insert/delete (the single concurrent inserter Thm 21 allows); processes
    1..c-1 hammer the same key with lookup/delete.  The O(c) interference
    (revalidate resurrections, DELETED handoffs, failed Modifies) lands on
    the inserter's step count."""
    rng = np.random.default_rng(seed)
    rows = []
    for c in cs:
        P = c
        op = np.zeros((P, K), dtype=np.int32)
        op[0, 0::2] = SCH.OP_INSERT
        op[0, 1::2] = SCH.OP_DELETE
        if P > 1:
            op[1:, 0::2] = SCH.OP_LOOKUP
            op[1:, 1::2] = SCH.OP_DELETE
        key = np.full((P, K), 7, dtype=np.uint32)
        wl = SCH.Workload(op=op, key=key)
        T = 800 * P * K
        sched = SCH.uniform_schedule(rng, P, T)
        st = SIM.simulate(wl, m, sched, mode=mode)
        rows.append({"c": c, "mean_steps": _mean_steps(st, wl),
                     "insert_steps": _mean_steps(st, wl, OP_INSERT)})
    return rows


def run(verbose: bool = True, fast: bool = False) -> dict:
    out = {}
    xs = (1.5, 2.0, 3.0) if fast else (1.5, 2.0, 3.0, 4.0)
    cs = (1, 2, 4) if fast else (1, 2, 4, 6)
    for mode in (SIM.MODE_LLSC, SIM.MODE_CAS):
        load = sweep_load(mode, xs=xs)
        cont = sweep_contention(mode, cs=cs)
        out[mode] = {"load": load, "contention": cont}
        if verbose:
            print(f"bench_steps [{mode}] — load-factor sweep (Thm 21 / Knuth)")
            print("      x    load   mean_steps   0.5(1+x^2)")
            for r in load:
                print(f"  {r['x']:5.1f}  {r['load']:5.2f}   "
                      f"{r['mean_steps']:9.2f}   {r['knuth_x2']:9.2f}")
            print(f"bench_steps [{mode}] — contention sweep (+O(c) term)")
            print("      c    mean_steps   insert_steps")
            for r in cont:
                print(f"  {r['c']:5d}   {r['mean_steps']:9.2f}   "
                      f"{r['insert_steps']:9.2f}")
        # soft validations: steps grow with x and stay O(x^2)-ish; the
        # contention curve grows no faster than ~linear + constant
        ms = [r["mean_steps"] for r in load]
        assert ms == sorted(ms), "steps not monotone in load"
        assert ms[-1] < 40 * load[-1]["knuth_x2"], "way off Knuth bound"
    return out
