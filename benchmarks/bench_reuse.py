"""Tombstone reuse vs the no-reuse baseline [7,14] under sustained churn.

Fixed live working set (W keys), repeated delete+insert batches.  The
paper's table: occupancy stays ~W/m forever (deleted slots are reclaimed).
No-reuse: occupancy (keys+tombstones) climbs monotonically to the rebuild
threshold — the periodic rebuild cost the paper eliminates.  Also replays
the same churn on the serving page-table (pages are the keys) — the
production integration of the same property.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.core.baselines import gao_noreuse as GN
from repro.serving import page_table as PT

LPT = PT.for_strategy("linear")  # the strategy-bound facade


def churn(module, m: int, working: int, rounds: int, seed: int = 0):
    """Returns (per-round occupancy, #rebuilds, #aborts).  Rebuild policy
    applies to the no-reuse module only (ours never rebuilds)."""
    ht = module.create(m)
    rng = np.random.default_rng(seed)
    keys = rng.choice(BT.E.MAX_KEY, size=working, replace=False).astype(
        np.uint32)
    ht, _ = module.insert_batch(ht, jnp.asarray(keys))
    occ, rebuilds, aborts = [], 0, 0
    for r in range(rounds):
        victims = rng.choice(working, size=working // 4, replace=False)
        ht, _ = module.delete_batch(ht, jnp.asarray(keys[victims]))
        fresh = rng.choice(BT.E.MAX_KEY, size=len(victims),
                           replace=False).astype(np.uint32)
        keys[victims] = fresh
        ht, ret = module.insert_batch(ht, jnp.asarray(fresh))
        aborts += int((np.asarray(ret) == 2).sum())
        if hasattr(module, "needs_rebuild") and bool(module.needs_rebuild(ht)):
            ht = module.rebuild(ht)
            rebuilds += 1
        occ.append(float(BT.occupancy(ht)))
    return occ, rebuilds, aborts


def _displacements(ht) -> np.ndarray:
    """Probe length (displacement from home bucket) of every live cell —
    the machine-independent lookup-cost profile of a table state."""
    tab = np.asarray(ht.table)
    m = tab.size
    occ = (tab != BT.E.EMPTY) & (tab != BT.E.TOMBSTONE)
    idx = np.nonzero(occ)[0]
    if idx.size == 0:
        return np.zeros((0,), np.int64)
    keys = (tab[idx] >> 2).astype(np.uint32)
    hv = np.asarray(BT._hash(ht, jnp.asarray(keys)))
    return (idx - hv) % m


def strategy_churn(m: int = 256, working: int = 96, rounds: int = 12,
                   seed: int = 3) -> dict:
    """The same fixed-working-set churn replayed under every probe strategy
    (core/probe_strategies.py): per-strategy probe-length percentiles of
    the final table and the tombstone-pressure curve (max / final count
    over the run).  Seeded and eager — every number is deterministic, so
    all of it is gated: robinhood must keep probe p99 <= linear's,
    hopscotch must stay at 0 tombstones and probe lengths < H."""
    from repro.core.probe_strategies import STRATEGIES, get_strategy
    out = {}
    for name in sorted(STRATEGIES):
        impl = get_strategy(name)
        ht = BT.create(m, seed=1, strategy=name)
        rng = np.random.default_rng(seed)
        keys = rng.choice(BT.E.MAX_KEY, size=working,
                          replace=False).astype(np.uint32)
        ht, _ = impl.insert_batch(ht, jnp.asarray(keys))
        tombs_curve, aborts = [], 0
        for _ in range(rounds):
            victims = rng.choice(working, size=working // 4, replace=False)
            ht, _ = impl.delete_batch(ht, jnp.asarray(keys[victims]))
            fresh = rng.choice(BT.E.MAX_KEY, size=len(victims),
                               replace=False).astype(np.uint32)
            keys[victims] = fresh
            ht, ret = impl.insert_batch(ht, jnp.asarray(fresh))
            aborts += int((np.asarray(ret) == 2).sum())
            tombs_curve.append(int(ht.num_tombs))
        d = _displacements(ht)
        out[name] = {
            "probe_p50": float(np.percentile(d, 50)) if d.size else 0.0,
            "probe_p99": float(np.percentile(d, 99)) if d.size else 0.0,
            "tombs_max": max(tombs_curve),
            "tombs_final": tombs_curve[-1],
            "aborts": aborts,
        }
    assert out["hopscotch"]["tombs_max"] == 0, \
        "hopscotch left tombstones under churn"
    return out


def page_churn(n_pages: int = 512, B: int = 16, page_size: int = 4,
               rounds: int = 40, seed: int = 1):
    """Same story on the paged-KV allocator: evict/admit sequences."""
    table = LPT.create_table(n_pages)
    rng = np.random.default_rng(seed)
    pos = np.zeros(B, np.int32)
    seq = np.arange(B, dtype=np.int32)
    next_id = B
    occ = []
    maxP = 16
    for r in range(rounds):
        for _ in range(8):
            table, slots, aborted = LPT.alloc_step(table, jnp.asarray(seq),
                                                  jnp.asarray(pos),
                                                  page_size=page_size)
            assert (np.asarray(slots) >= 0).all(), "allocator aborted"
            assert not np.asarray(aborted).any()
            pos += 1
        # evict half the sequences
        victims = rng.choice(B, size=B // 2, replace=False)
        mask = np.zeros(B, bool)
        mask[victims] = True
        table = LPT.free_sequences(table, jnp.asarray(seq), jnp.asarray(pos),
                                  page_size=page_size, max_pages=maxP,
                                  active=jnp.asarray(mask))
        for v in victims:
            seq[v] = next_id
            next_id += 1
            pos[v] = 0
        occ.append(float(BT.occupancy(table)))
    return occ


def page_exhaust_reclaim(n_pages: int = 16, B: int = 4, page_size: int = 2):
    """Pool-exhaustion lifecycle on the page allocator: fill every cell,
    count the ABORTs surfaced per lane (never a wrapped write_slot), evict
    half the sequences, and confirm the tombstoned slots are re-claimed by
    the very next alloc_step (Proposition 2 as an allocator).  Returns
    machine-independent gated counts."""
    table = LPT.create_table(n_pages)
    seq = jnp.arange(B, dtype=jnp.int32)
    steps_to_fill = (n_pages // B) * page_size
    aborts_seen = 0
    for pos in range(steps_to_fill + page_size):
        table, slots, aborted = LPT.alloc_step(
            table, seq, jnp.full((B,), pos, jnp.int32),
            page_size=page_size)
        assert (np.asarray(slots) >= -1).all()
        assert ((np.asarray(slots) >= 0) | np.asarray(aborted)
                | (pos % page_size != 0)).all(), "silent drop"
        aborts_seen += int(np.asarray(aborted).sum())
    full_occ = float(BT.occupancy(table))
    # evict half -> tombstones -> immediate reclaim, no rebuild
    half = B // 2
    table = LPT.free_sequences(
        table, seq[:half], jnp.full((half,), steps_to_fill, jnp.int32),
        page_size=page_size, max_pages=n_pages)
    tombs = int(table.num_tombs)
    fresh = jnp.arange(B, B + half, dtype=jnp.int32)
    table, slots, aborted = LPT.alloc_step(
        table, fresh, jnp.zeros((half,), jnp.int32), page_size=page_size)
    reclaimed = int((np.asarray(slots) >= 0).sum())
    assert not np.asarray(aborted).any()
    return {"aborts_surfaced": aborts_seen, "occ_at_exhaustion": full_occ,
            "tombstones_after_evict": tombs,
            "reclaimed_next_alloc": reclaimed}


def run(verbose: bool = True, fast: bool = False) -> dict:
    m, working, rounds = (256, 96, 20) if fast else (1024, 384, 40)
    ours_occ, ours_rebuilds, ours_aborts = churn(BT, m, working, rounds)
    base_occ, rebuilds, _ = churn(GN, m, working, rounds)
    pocc = page_churn(rounds=15 if fast else 40)
    exhaust = page_exhaust_reclaim()
    strategies = strategy_churn(rounds=8 if fast else 12)
    out = {"ours_final_occ": ours_occ[-1], "ours_max_occ": max(ours_occ),
           "ours_aborts": ours_aborts,
           "noreuse_rebuilds": rebuilds, "noreuse_final_occ": base_occ[-1],
           "page_table_max_occ": max(pocc),
           "page_exhaust": exhaust,
           "strategies": strategies}
    if verbose:
        print("bench_reuse — churn at fixed working set "
              f"(m={m}, live={working}, {rounds} rounds of 25% turnover)")
        print(f"  ours      : 0 rebuilds, {ours_aborts} aborts over "
              f"{rounds} rounds; occupancy equilibrates at "
              f"{ours_occ[-1]:.3f} (tombstones reclaimed when probe runs "
              f"cross them — Prop. 2: space is reusable, no rebuild ever "
              f"REQUIRED)")
        print(f"  no-reuse  : {rebuilds} rebuild(s) forced "
              f"(occupancy only grows; hits the 0.95 threshold)")
        print(f"  paged-KV  : page-slot occupancy <= {max(pocc):.3f} under "
              f"sequence churn; allocator never aborted")
        for name, s in strategies.items():
            print(f"  {name:<10}: probe p50/p99={s['probe_p50']:.0f}/"
                  f"{s['probe_p99']:.0f}  tombs max/final="
                  f"{s['tombs_max']}/{s['tombs_final']}  "
                  f"aborts={s['aborts']}")
    assert ours_rebuilds == 0 and ours_aborts == 0, \
        "ours should sustain churn without rebuilds or aborts"
    assert rebuilds >= 1, "baseline should have needed a rebuild"
    return out
