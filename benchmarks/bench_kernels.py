"""Pallas kernel benches (interpret mode on CPU: correctness + structural
roofline, not wall-clock).

probe kernel: shape/dtype sweep vs the jnp oracle; fast-path coverage
(fraction of lookups resolved inside the VMEM-resident window) at several
load factors — the TPU analog of the paper's "one cache line per lookup".
paged_attention kernel: allclose vs ref across head/page sweeps.

Structural roofline per kernel: VMEM working set from the BlockSpecs and
bytes/FLOPs per tile (HBM->VMEM DMA volume is the kernel's roofline term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.kernels.probe import ops as probe_ops
from repro.kernels.probe.probe import DEFAULT_KT, DEFAULT_TB, LANES


def probe_correctness(loads=(0.3, 0.6, 0.85), m: int = 1 << 14,
                      B: int = 1 << 10, seed: int = 0, verbose=True):
    rng = np.random.default_rng(seed)
    rows = []
    for load in loads:
        ht = BT.create(m)
        keys = rng.choice(BT.E.MAX_KEY, size=int(load * m),
                          replace=False).astype(np.uint32)
        for i in range(0, len(keys), 4096):
            chunk = keys[i:i + 4096]
            ht, _ = BT.insert_batch(ht, jnp.asarray(
                np.pad(chunk, (0, 4096 - len(chunk)))),
                active=jnp.arange(4096) < len(chunk))
        q = np.concatenate([rng.choice(keys, B // 2),
                            rng.choice(BT.E.MAX_KEY, B // 2)]).astype(
                                np.uint32)
        f_k, s_k = probe_ops.probe_lookup(ht, jnp.asarray(q), TB=2048,
                                          KT=128, interpret=True)
        f_r, s_r = BT.find_batch(ht, jnp.asarray(q))
        np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        cov = float(probe_ops.resolved_fraction(ht, jnp.asarray(q), TB=2048,
                                                KT=128, interpret=True))
        rows.append({"load": load, "fastpath_coverage": cov})
    if verbose:
        print("bench_kernels/probe — kernel == oracle at all loads; "
              "fast-path coverage:")
        for r in rows:
            print(f"  load {r['load']:4.2f}: {r['fastpath_coverage']:6.3f}")
    return rows


def probe_structural(TB: int = DEFAULT_TB, KT: int = DEFAULT_KT,
                     verbose=True):
    """VMEM working set + DMA volume per tile from the BlockSpecs."""
    vmem = {
        "table_blocks(u32)": 2 * TB * 4,
        "scratch(u32)": 2 * TB * 4,
        "keys+hv(u32/i32)": 2 * KT * 4,
        "outputs(i32x3)": 3 * KT * 4,
    }
    total = sum(vmem.values())
    dma_per_tile = 2 * TB * 4                      # two table blocks
    probe_bytes_per_key = dma_per_tile / KT        # amortized over the tile
    out = {"vmem_bytes": total, "dma_per_tile": dma_per_tile,
           "bytes_per_lookup": probe_bytes_per_key,
           "vmem_budget_ok": total < 16 * 2 ** 20}
    if verbose:
        print(f"  structural: VMEM/tile {total/2**10:.0f} KiB (<16 MiB ok), "
              f"HBM bytes/lookup {probe_bytes_per_key:.0f} "
              f"(sequential: >= {64} per cache line)")
    return out


def paged_attention_correctness(verbose=True):
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.kernels.paged_attention import ref as pa_ref
    rng = np.random.default_rng(0)
    rows = []
    for (B, H, hd, P, psize) in [(2, 2, 16, 8, 16), (2, 4, 32, 16, 8)]:
        pool_k = jnp.asarray(rng.normal(size=(P, psize, H, hd)),
                             jnp.float32)
        pool_v = jnp.asarray(rng.normal(size=(P, psize, H, hd)),
                             jnp.float32)
        n_pages = 4
        page_ids = jnp.asarray(rng.integers(0, P, size=(B, n_pages)),
                               jnp.int32)
        lengths = jnp.asarray(rng.integers(1, n_pages * psize, size=(B,)),
                              jnp.int32)
        q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
        out_k = pa_ops.paged_attention(q, pool_k, pool_v, page_ids, lengths,
                                       interpret=True)
        out_r = pa_ref.paged_attention_ref(q, pool_k, pool_v, page_ids,
                                           lengths)
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        rows.append({"shape": (B, H, hd, P, psize), "max_err": err})
        assert err < 2e-5, err
    if verbose:
        print("bench_kernels/paged_attention — kernel == ref:")
        for r in rows:
            print(f"  shape {r['shape']}: max_err {r['max_err']:.2e}")
    return rows


def fused_decode_bench(verbose=True):
    """Fused block-table-walk + paged-attention kernel: bitwise vs the
    two-dispatch composition it replaces, plus the structural HBM
    bytes-per-token counter (``kernels.stats``, noted on eager calls).
    The byte counts are deterministic (seeded snapshot), so the measured
    probe/attn reduction vs the two-dispatch baseline is GATED."""
    from repro.kernels import stats as KS
    from repro.kernels.fused_decode import (fused_decode_ref,
                                            fused_paged_attention)

    rng = np.random.default_rng(3)
    rows = []
    for (B, QH, KH, D, PS, MP) in [(4, 4, 4, 32, 8, 8), (4, 8, 2, 16, 4, 16)]:
        NP = B * MP
        k = jnp.asarray(rng.normal(size=(NP, PS, KH, D)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(NP, PS, KH, D)), jnp.bfloat16)
        q = jnp.asarray(rng.normal(size=(B, QH, D)), jnp.bfloat16)
        pos = jnp.asarray(rng.integers(PS, MP * PS, size=(B,)), jnp.int32)
        perm = rng.permutation(NP)
        bt = np.full((B, MP), -1, np.int32)
        for b in range(B):
            n_live = int(pos[b]) // PS + 1
            bt[b, :n_live] = perm[b * MP:b * MP + n_live]
        bt = jnp.asarray(bt)

        with KS.kernel_stats_scope() as st:
            out_k = fused_paged_attention(q, k, v, bt, pos, interpret=True)
            fused_probe = st["probe_bytes"]
            fused_attn = st["attn_bytes"]
        out_r = fused_decode_ref(q, k, v, bt, pos, interpret=True)
        bitwise = bool(np.array_equal(np.asarray(out_k), np.asarray(out_r)))
        assert bitwise, (B, QH, KH, D, PS, MP)

        # two-dispatch structural baseline: the materialized slot view is
        # written then re-read ([B,MP] i32 round trip) and the baseline
        # attention kernel walks every padded slot per kv head
        page_bytes = PS * D * (k.dtype.itemsize + v.dtype.itemsize)
        two_probe = 2 * B * MP * 4
        two_attn = B * KH * MP * page_bytes
        rows.append({
            "shape": (B, QH, KH, D, PS, MP),
            "bitwise": bitwise,
            "probe_bytes_per_token_twodispatch": two_probe / B,
            "probe_bytes_per_token_fused": fused_probe / B,
            "attn_bytes_per_token_twodispatch": two_attn / B,
            "attn_bytes_per_token_fused": fused_attn / B,
            "probe_bytes_reduction_x": two_probe / max(fused_probe, 1),
            "attn_bytes_reduction_x": two_attn / max(fused_attn, 1),
        })
    if verbose:
        print("bench_kernels/fused_decode — fused == two-dispatch (bitwise); "
              "HBM bytes/token:")
        for r in rows:
            print(f"  shape {r['shape']}: probe "
                  f"{r['probe_bytes_per_token_twodispatch']:.0f} -> "
                  f"{r['probe_bytes_per_token_fused']:.0f} "
                  f"({r['probe_bytes_reduction_x']:.1f}x), attn "
                  f"{r['attn_bytes_per_token_twodispatch']:.0f} -> "
                  f"{r['attn_bytes_per_token_fused']:.0f} "
                  f"({r['attn_bytes_reduction_x']:.2f}x)")
    return rows


def run(verbose: bool = True, fast: bool = False) -> dict:
    loads = (0.3, 0.6) if fast else (0.3, 0.6, 0.85)
    out = {
        "probe": probe_correctness(loads=loads, verbose=verbose,
                                   m=1 << 13 if fast else 1 << 14,
                                   B=256 if fast else 1 << 10),
        "probe_structural": probe_structural(verbose=verbose),
        "paged_attention": paged_attention_correctness(verbose=verbose),
        "fused_decode": fused_decode_bench(verbose=verbose),
    }
    return out
