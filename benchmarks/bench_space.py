"""Table 1 reproduction: per-cell bits across designs, for a sweep of key
domains.  Pure accounting (core/encoding.py) — the paper's space claim."""
from __future__ import annotations

from repro.core import encoding as E


def run(verbose: bool = True) -> dict:
    rows = []
    for log_u in (16, 24, 28, 32, 48):
        U = 2 ** log_u
        n, m = 256, 1 << 20
        ours_llsc = E.cell_size_llsc(U).total
        ours_cas = E.cell_size_cas(U, n, m).total
        rows.append({
            "log2_U": log_u,
            "ours_llsc": ours_llsc,
            "ours_cas": ours_cas,
            "gao_noreuse[7,14]": E.cell_size_gao(U),
            "robinhood[3]": E.cell_size_robinhood(U),
            "shun_blelloch[20]": E.cell_size_shun_blelloch(U),
            "purcell_harris[18]": E.cell_size_purcell_harris_lower_bound(U),
        })
    if verbose:
        hdr = list(rows[0])
        print("bench_space (bits per cell — Table 1)")
        print(" | ".join(f"{h:>20s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{r[h]:>20}" for h in hdr))
        # headline checks (Theorem 1)
        U = 2 ** 28 - 2
        assert E.cell_size_llsc(U).total == E._clog2(U + 1) + 2
        print("Theorem 1 bit counts verified (LL/SC: ceil(log(U+1))+2; "
              "CAS: +min(log n, log m))")
    return {"rows": rows}
