"""Batched-table throughput (jit, CPU host): Mops/s for insert / lookup /
delete / mixed at several load factors, ours vs the no-reuse baseline.
CPU numbers are for relative comparison (the TPU path is the probe kernel,
validated in interpret mode; see bench_kernels).

Also the decode hot path: megastep tokens/s at K in {1, 4, 16} (wall-clock,
report-only) and the machine-independent ``probes_per_token`` counter —
keys probed per decode token by the incremental block-table cache vs the
full O(B·max_pages) re-probe it replaced (deterministic counts, gated in
check_regression).

And the scheduler (``repro.serving.sched``): the adversarial admission
storm on a 2x-overcommitted pool, proactive vs reactive.  The abort /
avoided / preemption / grow counts are virtual-clock deterministic and
GATED (the proactive run must stay at 0 aborts); the queue-wait and
time-to-first-token percentiles are REPORT-ONLY (ISSUE 5)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.core.spec import OP_DELETE, OP_INSERT, OP_LOOKUP


def _time(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def probes_per_token(B: int = 8, max_pages: int = 64, page_size: int = 4,
                     tokens: int = 16) -> dict:
    """Machine-independent probe accounting: replay ``tokens`` decode steps
    on the page-table layer alone (eager, so the PT.PROBE_STATS counter
    sees concrete counts) under (a) the old full re-probe — ``alloc_step``
    + ``lookup_pages`` per token — and (b) the incremental block-table
    cache — ``alloc_step_incremental`` only.  The counts are exact and
    deterministic, so both rates and their ratio are gated."""
    from repro.serving import page_table as PT
    LPT = PT.for_strategy("linear")
    n_pages = B * max_pages
    seq = jnp.arange(B, dtype=jnp.int32)

    PT.probe_stats_reset()
    table = LPT.create_table(n_pages)
    for pos in range(tokens):
        p = jnp.full((B,), pos, jnp.int32)
        table, _, _ = LPT.alloc_step(table, seq, p, page_size=page_size)
        LPT.lookup_pages(table, seq, p, page_size=page_size,
                        max_pages=max_pages)
    full = PT.PROBE_STATS["keys_probed"] / tokens

    PT.probe_stats_reset()
    table = LPT.create_table(n_pages)
    bt = jnp.full((B, max_pages), -1, jnp.int32)
    for pos in range(tokens):
        p = jnp.full((B,), pos, jnp.int32)
        (table, ws, ab), bt = LPT.alloc_step_incremental(
            table, seq, p, bt, page_size=page_size)
        assert not bool(jnp.any(ab)) and bool(jnp.all(ws >= 0))
    incr = PT.PROBE_STATS["keys_probed"] / tokens
    assert int(LPT.verify_block_table(table, seq,
                                     jnp.full((B,), tokens - 1, jnp.int32),
                                     bt, page_size=page_size)) == 0
    PT.probe_stats_reset()
    return {"probes_per_token_full": full,
            "probes_per_token_incremental": incr,
            "probe_reduction_x": full / max(incr, 1e-9)}


def bytes_per_token(B: int = 8, max_pages: int = 64, page_size: int = 4,
                    tokens: int = 16) -> dict:
    """HBM bytes moved per decode token, on a block table grown by a real
    ``alloc_step_incremental`` replay: the two-dispatch slots+attend
    composition (structural: slot-view round trip + every padded slot per
    kv head) vs the fused kernel's ``kernels.stats`` counter (noted on the
    eager dispatch — raw table rows once, live pages only).  Deterministic
    replay, so the per-token counts and the reduction are gated."""
    from repro.kernels import stats as KS
    from repro.kernels.fused_decode import fused_paged_attention
    from repro.serving import page_table as PT
    LPT = PT.for_strategy("linear")

    seq = jnp.arange(B, dtype=jnp.int32)
    table = LPT.create_table(B * max_pages)
    bt = jnp.full((B, max_pages), -1, jnp.int32)
    for pos in range(tokens):
        p = jnp.full((B,), pos, jnp.int32)
        (table, ws, ab), bt = LPT.alloc_step_incremental(
            table, seq, p, bt, page_size=page_size)
        assert not bool(jnp.any(ab))

    KH, D = 2, 8
    k = jnp.zeros((B * max_pages, page_size, KH, D), jnp.bfloat16)
    v = jnp.zeros_like(k)
    q = jnp.zeros((B, KH, D), jnp.bfloat16)
    positions = jnp.full((B,), tokens - 1, jnp.int32)
    with KS.kernel_stats_scope() as st:
        fused_paged_attention(q, k, v, bt, positions, interpret=True)
        fused_probe, fused_attn = st["probe_bytes"], st["attn_bytes"]

    page_bytes = page_size * D * (k.dtype.itemsize + v.dtype.itemsize)
    two_probe = 2 * B * max_pages * 4
    two_attn = B * KH * max_pages * page_bytes
    return {"probe_bytes_per_token_twodispatch": two_probe / B,
            "probe_bytes_per_token_fused": fused_probe / B,
            "attn_bytes_per_token_twodispatch": two_attn / B,
            "attn_bytes_per_token_fused": fused_attn / B,
            "probe_bytes_reduction_x": two_probe / max(fused_probe, 1),
            "attn_bytes_reduction_x": two_attn / max(fused_attn, 1)}


def strategy_page_churn(n_pages: int = 256, B: int = 8, page_size: int = 4,
                        rounds: int = 10, seed: int = 2) -> dict:
    """The decode-allocator eviction churn replayed through the strategy
    facade (``PT.for_strategy``) for every probe strategy: per-strategy
    probe-length p99 of the final pool and the tombstone-pressure curve.
    Seeded eager replay — deterministic, gated: hopscotch must hold 0
    tombstones while linear/robinhood carry the churn's tombstone load."""
    from repro.core.probe_strategies import STRATEGIES
    from repro.serving import page_table as PT
    LPT = PT.for_strategy("linear")

    out = {}
    for name in sorted(STRATEGIES):
        pt = PT.for_strategy(name)
        table = pt.create_table(n_pages)
        rng = np.random.default_rng(seed)
        pos = np.zeros(B, np.int32)
        seq = np.arange(B, dtype=np.int32)
        next_id = B
        maxP = 16
        tombs_curve, aborts = [], 0
        for _ in range(rounds):
            for _ in range(8):
                st = pt.alloc_step(table, jnp.asarray(seq),
                                   jnp.asarray(pos), page_size=page_size)
                table = st.table
                aborts += int(np.asarray(st.aborted).sum())
                pos += 1
            victims = rng.choice(B, size=B // 2, replace=False)
            mask = np.zeros(B, bool)
            mask[victims] = True
            table = pt.free_sequences(table, jnp.asarray(seq),
                                      jnp.asarray(pos),
                                      page_size=page_size, max_pages=maxP,
                                      active=jnp.asarray(mask))
            for v in victims:
                seq[v] = next_id
                next_id += 1
                pos[v] = 0
            tombs_curve.append(int(table.num_tombs))
        p99 = pt.probe_p99(table)
        out[name] = {"page_probe_p99": p99,
                     "page_tombs_max": max(tombs_curve),
                     "page_tombs_final": tombs_curve[-1],
                     "page_aborts": aborts}
    assert out["hopscotch"]["page_tombs_max"] == 0
    return out


def decode_tok_s(fast: bool) -> dict:
    """Decode tokens/s THROUGH the serving stack at K in {1, 4, 16}: the
    same ``ContinuousBatcher`` + scheduler round loop production runs, with
    the telemetry counter plane on — the numerator is the device plane's
    ``tokens_accepted`` counter (exactly the committed decode tokens, not
    B*K optimism), the denominator wall-clock over a drained storm.
    Report-only like every wall-clock metric; per-request TPOT percentiles
    (virtual-clock steps/token, "tpot" marker) ride along from the same
    storm."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.launch.serve import ContinuousBatcher
    from repro.models.registry import get_model
    from repro.serving.sched import Scheduler, synthetic_workload
    from repro.serving.sched.scheduler import latency_percentiles

    cfg = dataclasses.replace(get_smoke_config("qwen2.5-32b"),
                              telemetry=True)
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    B, max_len, psize = 4, 32, 4
    n_req = 6 if fast else 10
    out = {}
    tpot_sched = None
    for K in (1, 4, 16):
        sched = Scheduler(slots=B, page_size=psize, max_len=max_len,
                          megastep_k=K, policy="fcfs", proactive=True)
        srv = ContinuousBatcher(cfg, params, batch=B, max_len=max_len,
                                page_size=psize, megastep_k=K,
                                scheduler=sched, n_pages=16,
                                auto_refill=False)
        # warm-up drain compiles the megastep so the timed drain measures
        # the steady round loop, not XLA
        sched.submit_many(synthetic_workload(
            B, vocab_size=cfg.vocab_size, max_len=max_len, seed=1,
            prompt_len=(2, 4), max_new=(6, 8)))
        assert srv.run_until_drained(max_rounds=400)
        tok0 = srv.metrics.snapshot()["counters"].get("tokens_accepted", 0)

        sched.submit_many(synthetic_workload(
            n_req, vocab_size=cfg.vocab_size, max_len=max_len, seed=0,
            prompt_len=(2, 5), max_new=(18, 26)))
        t0 = time.perf_counter()
        assert srv.run_until_drained(max_rounds=1000), "storm did not drain"
        dt = time.perf_counter() - t0
        tokens = (srv.metrics.snapshot()["counters"]["tokens_accepted"]
                  - tok0)
        assert tokens > 0
        out[f"tok_s_K{K}"] = tokens / dt
        if K == 4:
            tpot_sched = sched
    lat = latency_percentiles(tpot_sched.finished)
    out["tpot_p50_steps"] = lat["tpot_p50"]
    out["tpot_p99_steps"] = lat["tpot_p99"]
    return out


def telemetry_overhead(fast: bool) -> dict:
    """Wall-clock cost of the counter plane: the SAME jitted megastep run
    over a telemetry-off state and a telemetry-on state (the knob only
    changes state creation — the step keys on the presence of the
    ``counters`` leaf, so the two states trace to two cached programs).
    The ratio is gated as an absolute budget (<= 1.05) in
    ``check_regression.BUDGETS`` — the zero-sync design means the plane
    may cost at most scalar adds."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.registry import get_model
    from repro.serving import engine as EG

    cfg_off = get_smoke_config("qwen2.5-32b")
    cfg_on = dataclasses.replace(cfg_off, telemetry=True)
    model = get_model(cfg_off)
    params, _ = model.init(cfg_off, jax.random.PRNGKey(0))
    # S_max covers warm-up + every timed token (8 + reps*iters*8 <= 128)
    B, S_max, psize, K = 4, 128, 4, 8
    mega = jax.jit(EG.make_serve_megastep(cfg_off, S_max=S_max, K=K,
                                          page_size=psize))

    states, toks = {}, {}
    for name, cfg in (("off", cfg_off), ("on", cfg_on)):
        st, _ = EG.make_decode_state(cfg, B, S_max=S_max, page_size=psize)
        t, st = mega(params, st, jnp.zeros((B, 1), jnp.int32))  # compile
        jax.block_until_ready(t)
        states[name], toks[name] = st, t

    def timed(name, iters):
        st, t = states[name], toks[name]
        t0 = time.perf_counter()
        for _ in range(iters):
            t, st = mega(params, st, t[:, -1:])
        jax.block_until_ready(t)
        states[name], toks[name] = st, t
        return (time.perf_counter() - t0) / iters

    reps, iters = (2, 3) if fast else (3, 4)
    best = {"off": float("inf"), "on": float("inf")}
    for _ in range(reps):                   # interleave to decorrelate drift
        for name in ("off", "on"):
            best[name] = min(best[name], timed(name, iters))
    for name in ("off", "on"):
        assert not bool(jnp.any(states[name]["aborted"]))
    return {"telemetry_overhead_x": best["on"] / best["off"]}


def sched_storm(fast: bool) -> dict:
    """Adversarial admit-rate >> drain-rate churn through the scheduler on
    a 2x-overcommitted pool (smoke model, CPU).  All counts are
    virtual-clock deterministic, so the headline claims are gated:
    ``sched_aborts_proactive`` == 0 (the forecaster provably avoids ABORT)
    while ``sched_aborts_reactive`` >= 1 on the identical workload, with
    ``aborts_avoided`` / ``preemptive_evictions`` counting the proactive
    interventions.  Queue-wait / TTFT percentiles are report-only."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import ContinuousBatcher
    from repro.models.registry import get_model
    from repro.serving.sched import Request, Scheduler, synthetic_workload

    cfg = get_smoke_config("qwen2.5-32b")
    model = get_model(cfg)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))

    def run_storm(proactive, policy, wl, n_pages, **sched_kw):
        sched = Scheduler(slots=4, page_size=4, max_len=32, megastep_k=4,
                          policy=policy, proactive=proactive, **sched_kw)
        srv = ContinuousBatcher(cfg, params, batch=4, max_len=32,
                                page_size=4, megastep_k=4, scheduler=sched,
                                n_pages=n_pages, auto_refill=False)
        sched.submit_many(wl)
        assert srv.run_until_drained(max_rounds=400), "storm did not drain"
        return sched

    storm = synthetic_workload(10, vocab_size=cfg.vocab_size, max_len=32,
                               seed=0, prompt_len=(2, 5), max_new=(18, 26))
    on = run_storm(True, "fcfs", storm, 16)
    off = run_storm(False, "fcfs", [Request(
        req_id=r.req_id, prompt=r.prompt,
        max_new_tokens=r.max_new_tokens) for r in storm], 16)
    # priority pressure with growth disabled: preemptive evictions
    wl = [Request(req_id=i, prompt=np.full(2, 7, np.int32),
                  max_new_tokens=26, priority=0) for i in range(4)]
    wl += [Request(req_id=10 + i, prompt=np.full(2, 9, np.int32),
                   max_new_tokens=10, priority=5, arrival=8)
           for i in range(4)]
    pre = run_storm(True, "priority", wl, 20, allow_grow=False)

    lat = on.latency_summary()
    return {
        # gated (deterministic virtual-clock counts)
        "sched_aborts_proactive": on.stats.aborts,
        "sched_aborts_reactive": off.stats.aborts,
        "aborts_avoided": on.stats.aborts_avoided + pre.stats.aborts_avoided,
        "preemptive_evictions": pre.stats.preemptive_evictions,
        "sched_pool_grows": on.stats.pool_grows,
        "sched_completed": on.stats.completed + off.stats.completed
                           + pre.stats.completed,
        "sched_preempt_aborts": pre.stats.aborts,
        # report-only latency percentiles (virtual-clock steps)
        "queue_wait_p50_steps": lat["queue_wait_p50"],
        "queue_wait_p99_steps": lat["queue_wait_p99"],
        "ttft_p50_steps": lat["ttft_p50"],
        "ttft_p99_steps": lat["ttft_p99"],
    }


def sharded_routing(fast: bool) -> dict:
    """Cross-shard routing overhead: the SAME admission storm replayed
    through the hash-prefix-sharded page table (``serving/sharded_table`` +
    ``sched/router``, S shards) and through a single-shard instance of the
    identical stack (S=1 — the routing layer with routing a no-op).  Both
    runs go through the simulated multi-host harness
    (``tests/_multihost.SimCluster``), model replaced by the virtual clock.

    Gated (deterministic virtual-clock / probe-counter replays): probes per
    nominal decode token for each flavour and their ratio (the routing
    overhead), zero proactive aborts on both, completed == submitted, and
    the per-flavour round counts.  Queue-wait / TTFT percentiles (virtual
    steps) are report-only — admission latency under sharding."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tests"))
    import _multihost as MH

    from repro.serving import page_table as PT
    from repro.serving.sched import synthetic_workload

    hosts = 2 if fast else 4
    n_req = 8 if fast else 16
    max_len = 24

    def storm(n_shards):
        # capacity held fixed GLOBALLY so S=1 vs S shards compare the
        # routing, not the pool size
        wl = synthetic_workload(n_req, vocab_size=256, max_len=max_len,
                                seed=0, prompt_len=(2, 5), max_new=(12, 18))
        cluster = MH.SimCluster(
            hosts=n_shards, pages_per_shard=hosts * 24 // n_shards,
            slots_per_shard=hosts * 3 // n_shards, page_size=4,
            max_len=max_len, megastep_k=4, fail_on_abort=True)
        PT.probe_stats_reset()
        s = cluster.run_storm(wl, max_rounds=400)
        probes = PT.PROBE_STATS["keys_probed"]
        PT.probe_stats_reset()
        tokens = sum(min(r.total_len, max_len)
                     for r in cluster.router.finished())
        return s, probes / max(tokens, 1)

    s_many, ppt_many = storm(hosts)
    s_one, ppt_one = storm(1)
    assert int(s_many["completed"]) == int(s_many["submitted"])
    assert int(s_one["completed"]) == int(s_one["submitted"])
    return {
        # gated
        "shards": hosts,
        "probes_per_token_sharded": ppt_many,
        "probes_per_token_single": ppt_one,
        "routing_overhead_x": ppt_many / max(ppt_one, 1e-9),
        "sharded_aborts": int(s_many["aborts_observed"]),
        "single_aborts": int(s_one["aborts_observed"]),
        "sharded_completed": int(s_many["completed"]),
        "sharded_rounds": int(s_many["rounds"]),
        "single_rounds": int(s_one["rounds"]),
        "sharded_pool_grows": int(s_many["pool_grows"]),
        # report-only admission latency (virtual-clock steps)
        "sharded_queue_wait_p99_steps": s_many["queue_wait_p99"],
        "single_queue_wait_p99_steps": s_one["queue_wait_p99"],
        "sharded_ttft_p99_steps": s_many["ttft_p99"],
        "single_ttft_p99_steps": s_one["ttft_p99"],
    }


def run(verbose: bool = True, fast: bool = False) -> dict:
    m = 1 << 14 if fast else 1 << 16
    B = 1 << 10 if fast else 1 << 12
    rng = np.random.default_rng(0)
    rows = []
    for load in (0.5, 0.75, 0.9):
        ht = BT.create(m)
        n0 = int(load * m)
        base = rng.choice(BT.E.MAX_KEY, size=n0, replace=False).astype(
            np.uint32)
        for i in range(0, n0, B):
            ht, _ = BT.insert_batch(ht, jnp.asarray(
                np.pad(base[i:i + B], (0, max(0, B - len(base[i:i + B]))))))
        present = jnp.asarray(base[:B])
        absent = jnp.asarray(
            rng.choice(BT.E.MAX_KEY, size=B).astype(np.uint32))

        lookup = jax.jit(BT.lookup_batch)
        t_hit = _time(lookup, ht, present)
        t_miss = _time(lookup, ht, absent)
        ops = jnp.asarray(rng.integers(0, 3, size=B), jnp.int32)
        mixed_keys = jnp.where(jnp.asarray(rng.random(B) < 0.5), present,
                               absent)
        apply_b = jax.jit(BT.apply_batch)
        t_mixed = _time(apply_b, ht, ops, mixed_keys)
        rows.append({"load": load,
                     "lookup_hit_Mops": B / t_hit / 1e6,
                     "lookup_miss_Mops": B / t_miss / 1e6,
                     "mixed_Mops": B / t_mixed / 1e6})
    probes = probes_per_token()
    hbm = bytes_per_token()
    strat = strategy_page_churn(rounds=6 if fast else 10)
    decode = decode_tok_s(fast)
    telem = telemetry_overhead(fast)
    sched = sched_storm(fast)
    routed = sharded_routing(fast)
    if verbose:
        print(f"bench_throughput (jit CPU, m={m}, batch={B})")
        print("   load   lookup-hit   lookup-miss   mixed  [Mops/s]")
        for r in rows:
            print(f"  {r['load']:5.2f}   {r['lookup_hit_Mops']:9.2f}   "
                  f"{r['lookup_miss_Mops']:10.2f}   {r['mixed_Mops']:6.2f}")
        print(f"  decode probes/token: full={probes['probes_per_token_full']:.1f} "
              f"incremental={probes['probes_per_token_incremental']:.1f} "
              f"({probes['probe_reduction_x']:.0f}x fewer)")
        print(f"  decode HBM bytes/token: probe "
              f"{hbm['probe_bytes_per_token_twodispatch']:.0f} -> "
              f"{hbm['probe_bytes_per_token_fused']:.0f} "
              f"({hbm['probe_bytes_reduction_x']:.1f}x), attn "
              f"{hbm['attn_bytes_per_token_twodispatch']:.0f} -> "
              f"{hbm['attn_bytes_per_token_fused']:.0f} "
              f"({hbm['attn_bytes_reduction_x']:.2f}x)")
        for name, s in strat.items():
            print(f"  alloc churn [{name}]: probe p99="
                  f"{s['page_probe_p99']:.0f}  tombs max/final="
                  f"{s['page_tombs_max']}/{s['page_tombs_final']}  "
                  f"aborts={s['page_aborts']}")
        print("  decode tok/s (batcher path): "
              + "  ".join(f"K{k.split('_K')[1]}={v:.1f}"
                          for k, v in decode.items() if "_K" in k)
              + f"  tpot p50/p99={decode['tpot_p50_steps']:.1f}/"
                f"{decode['tpot_p99_steps']:.1f} steps/tok (report-only)")
        print(f"  telemetry overhead: "
              f"{telem['telemetry_overhead_x']:.3f}x megastep wall-clock "
              f"(budget <= 1.05)")
        print(f"  sched storm: aborts proactive="
              f"{sched['sched_aborts_proactive']} vs reactive="
              f"{sched['sched_aborts_reactive']}; "
              f"avoided={sched['aborts_avoided']} "
              f"preempt={sched['preemptive_evictions']} "
              f"grows={sched['sched_pool_grows']}; "
              f"ttft p50/p99={sched['ttft_p50_steps']:.0f}/"
              f"{sched['ttft_p99_steps']:.0f} steps (report-only)")
        print(f"  sharded routing (S={routed['shards']} vs 1): "
              f"probes/token {routed['probes_per_token_sharded']:.1f} vs "
              f"{routed['probes_per_token_single']:.1f} "
              f"({routed['routing_overhead_x']:.2f}x); aborts="
              f"{routed['sharded_aborts']}; ttft p99 "
              f"{routed['sharded_ttft_p99_steps']:.0f} vs "
              f"{routed['single_ttft_p99_steps']:.0f} steps (report-only)")
    return {"rows": rows, "decode": {**probes, **hbm, **decode},
            "telemetry": telem, "strategies": strat, "sched": sched,
            "sharded_routing": routed}
