"""Batched-table throughput (jit, CPU host): Mops/s for insert / lookup /
delete / mixed at several load factors, ours vs the no-reuse baseline.
CPU numbers are for relative comparison (the TPU path is the probe kernel,
validated in interpret mode; see bench_kernels)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.core.spec import OP_DELETE, OP_INSERT, OP_LOOKUP


def _time(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(verbose: bool = True, fast: bool = False) -> dict:
    m = 1 << 14 if fast else 1 << 16
    B = 1 << 10 if fast else 1 << 12
    rng = np.random.default_rng(0)
    rows = []
    for load in (0.5, 0.75, 0.9):
        ht = BT.create(m)
        n0 = int(load * m)
        base = rng.choice(BT.E.MAX_KEY, size=n0, replace=False).astype(
            np.uint32)
        for i in range(0, n0, B):
            ht, _ = BT.insert_batch(ht, jnp.asarray(
                np.pad(base[i:i + B], (0, max(0, B - len(base[i:i + B]))))))
        present = jnp.asarray(base[:B])
        absent = jnp.asarray(
            rng.choice(BT.E.MAX_KEY, size=B).astype(np.uint32))

        lookup = jax.jit(BT.lookup_batch)
        t_hit = _time(lookup, ht, present)
        t_miss = _time(lookup, ht, absent)
        ops = jnp.asarray(rng.integers(0, 3, size=B), jnp.int32)
        mixed_keys = jnp.where(jnp.asarray(rng.random(B) < 0.5), present,
                               absent)
        apply_b = jax.jit(BT.apply_batch)
        t_mixed = _time(apply_b, ht, ops, mixed_keys)
        rows.append({"load": load,
                     "lookup_hit_Mops": B / t_hit / 1e6,
                     "lookup_miss_Mops": B / t_miss / 1e6,
                     "mixed_Mops": B / t_mixed / 1e6})
    if verbose:
        print(f"bench_throughput (jit CPU, m={m}, batch={B})")
        print("   load   lookup-hit   lookup-miss   mixed  [Mops/s]")
        for r in rows:
            print(f"  {r['load']:5.2f}   {r['lookup_hit_Mops']:9.2f}   "
                  f"{r['lookup_miss_Mops']:10.2f}   {r['mixed_Mops']:6.2f}")
    return {"rows": rows}
