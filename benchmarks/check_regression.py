"""Perf-regression gate: compare a fresh ``benchmarks.run --smoke`` result
(results/benchmarks.json) against the committed baseline
(benchmarks/baseline.json) and fail when a gated metric drifts beyond the
tolerance (default ±15%).

Gated metrics are machine-independent by construction: bit counts (space),
simulator step counts (steps), occupancy / rebuild / abort counts (reuse),
fast-path coverage and structural VMEM/DMA bytes (kernels), roofline
fractions.  Wall-clock metrics (``*Mops*``) depend on the runner and are
reported but never gated — the smoke sizes are far too small for stable
timing on shared CI.

Usage:
  python -m benchmarks.check_regression [--baseline benchmarks/baseline.json]
      [--results results/benchmarks.json] [--tolerance 0.15]

Regenerate the baseline after an intentional perf/behavior change:
  python -m benchmarks.run --smoke && \
      cp results/benchmarks.json benchmarks/baseline.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys

# report-only: wall-clock throughput (runner-dependent) and fp comparison
# residuals (BLAS/ISA-dependent; correctness is gated by the pytest suite).
# "tok_s": decode megastep tokens/s — wall-clock like Mops.  The decode
# probes_per_token_* / probe_reduction_x counts are deterministic replays
# and stay GATED, as are the fused-kernel HBM byte counters
# (probe_bytes_per_token_* / attn_bytes_per_token_* / *_bytes_reduction_x:
# structural accounting over seeded snapshots, exactly reproducible); so are the scheduler storm's abort/avoided/preemption
# counts (virtual-clock).  The scheduler queue-wait / TTFT / TPOT
# percentiles are report-only per ISSUES 5 and 10 ("queue_wait" / "ttft" /
# "tpot" markers).
NOISY_MARKERS = ("Mops", "max_err", "tok_s", "queue_wait", "ttft", "tpot")

# Absolute upper bounds (metric-path suffix -> max allowed value): these
# are gated against the BOUND, not against baseline drift — the wall-clock
# RATIO of two interleaved runs of the same program is stable even where
# the runs themselves are not.  telemetry_overhead_x is the ISSUE 10
# zero-sync claim: the counter plane may cost at most 5% of the megastep.
BUDGETS = {"telemetry_overhead_x": 1.05}


def flatten(tree, prefix="", out=None):
    """dict/list tree -> {path: numeric leaf} (non-numeric leaves skipped)."""
    if out is None:
        out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            flatten(v, f"{prefix}/{k}" if prefix else str(k), out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            flatten(v, f"{prefix}/{i}", out)
    elif isinstance(tree, bool):
        out[prefix] = float(tree)
    elif isinstance(tree, (int, float)):
        out[prefix] = float(tree)
    return out


def is_noisy(path: str) -> bool:
    return any(m in path for m in NOISY_MARKERS)


def budget_of(path: str):
    for suffix, bound in BUDGETS.items():
        if path.endswith(suffix):
            return bound
    return None


def compare(baseline: dict, results: dict, tolerance: float):
    """Returns (failures, noisy_report, missing, ungated) lists of strings.
    ``ungated``: metrics present in results but not in the baseline — not a
    failure, but surfaced so new benches don't silently escape the gate."""
    base = flatten(baseline)
    new = flatten(results)
    failures, noisy, missing = [], [], []
    ungated = sorted(set(new) - set(base))
    # absolute budgets gate the RESULTS alone (baseline presence is not
    # required — a budgeted metric may never silently exceed its bound)
    for path, n in sorted(new.items()):
        bound = budget_of(path)
        if bound is None:
            continue
        if not math.isfinite(n) or n > bound:
            failures.append(f"{path}: {n:.6g} exceeds budget <= {bound}")
    for path, b in sorted(base.items()):
        if path not in new:
            missing.append(path)
            continue
        if budget_of(path) is not None:
            continue                     # gated by the bound above, not drift
        n = new[path]
        if not (math.isfinite(b) and math.isfinite(n)):
            if math.isnan(b) and math.isnan(n):
                continue
            failures.append(f"{path}: baseline={b} now={n} (non-finite)")
            continue
        denom = max(abs(b), 1e-12)
        rel = abs(n - b) / denom
        line = f"{path}: baseline={b:.6g} now={n:.6g} drift={rel * 100:.1f}%"
        if is_noisy(path):
            noisy.append(line)
        elif rel > tolerance:
            failures.append(line)
    return failures, noisy, missing, ungated


def print_diff_table(baseline: dict, results: dict, tolerance: float):
    """Full per-metric diff table (every gated metric, not just the
    failures) — printed on failure so a red gate shows the whole landscape
    at once instead of forcing a local re-run to see what else moved."""
    base = flatten(baseline)
    new = flatten(results)
    rows = []
    for path, b in sorted(base.items()):
        if is_noisy(path) or budget_of(path) is not None:
            continue
        if path not in new:
            rows.append((path, b, float("nan"), float("nan"), "MISSING"))
            continue
        n = new[path]
        if not (math.isfinite(b) and math.isfinite(n)):
            status = "ok" if (math.isnan(b) and math.isnan(n)) else "FAIL"
            rows.append((path, b, n, float("nan"), status))
            continue
        rel = abs(n - b) / max(abs(b), 1e-12)
        rows.append((path, b, n, rel, "FAIL" if rel > tolerance else "ok"))
    w = max((len(r[0]) for r in rows), default=10)
    print(f"\nfull gated diff table ({len(rows)} metrics):")
    print(f"  {'metric':<{w}}  {'baseline':>12}  {'now':>12}  "
          f"{'drift':>7}  status")
    for path, b, n, rel, status in rows:
        drift = f"{rel * 100:.1f}%" if math.isfinite(rel) else "-"
        print(f"  {path:<{w}}  {b:>12.6g}  {n:>12.6g}  {drift:>7}  {status}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--results", default="results/benchmarks.json")
    ap.add_argument("--tolerance", type=float, default=0.15)
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.results) as f:
        results = json.load(f)

    failures, noisy, missing, ungated = compare(baseline, results,
                                                args.tolerance)
    n_gated = len(flatten(baseline)) - len(noisy) - len(missing)
    print(f"check_regression: {n_gated} gated metrics vs {args.baseline} "
          f"(tolerance ±{args.tolerance * 100:.0f}%)")
    if ungated:
        print(f"\n{len(ungated)} NEW metrics not in the baseline (ungated — "
              "regenerate benchmarks/baseline.json to gate them):")
        for path in ungated:
            print("  ", path)
    if noisy:
        print(f"\n{len(noisy)} wall-clock metrics (report-only):")
        for line in noisy:
            print("  ", line)
    if missing:
        print(f"\n{len(missing)} baseline metrics missing from results "
              "(did a bench get dropped? regenerate the baseline):")
        for line in missing:
            print("  ", line)
    if failures:
        print(f"\nFAIL — {len(failures)} metrics drifted beyond tolerance:")
        for line in failures:
            print("  ", line)
        print_diff_table(baseline, results, args.tolerance)
    ok = not failures and not missing
    print("\ncheck_regression:", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
