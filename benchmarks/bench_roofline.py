"""Aggregate the dry-run artifacts (results/dryrun/*.json) into the
§Roofline table: three terms per (arch × shape × mesh), dominant bottleneck,
MODEL_FLOPS ratio, and a one-line what-would-move-it note."""
from __future__ import annotations

import glob
import json
import os

NOTES = {
    ("train", "collective"): "shrink TP activation ARs (bf16 psum, "
                             "Megatron-SP residual sharding, or FSDP-only "
                             "mapping for small models)",
    ("train", "compute"): "near roofline for this mapping; remat policy "
                          "(save attn outputs) trims the 4x->3x multiplier",
    ("train", "memory"): "activation traffic: fuse norms/rope, larger "
                         "per-chip batch",
    ("prefill", "collective"): "same TP ARs as train without the bwd "
                               "amortization — SP or wider data axis",
    ("prefill", "compute"): "attention triangle + MLP dominate; near "
                            "roofline",
    ("prefill", "memory"): "KV write traffic; fuse rope+cache-write",
    ("decode", "memory"): "KV-pool reads dominate: int8 KV (2x), tighter "
                          "page capacity (2x->1.2x gather waste)",
    ("decode", "collective"): "per-layer q/o gathers: batch layers' "
                              "collectives or widen model axis",
    ("decode", "compute"): "unusual for decode — check capacity waste",
}


def load_records(out_dir: str = "results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table_rows(recs):
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skipped",
                         "note": r["reason"][:60]})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "ERROR",
                         "note": r.get("error", "?")[:60]})
            continue
        rl = r["roofline"]
        kind = r.get("kind", "train")
        ov = r.get("overrides", {})
        variant = ",".join(f"{k}={v}" for k, v in ov.items()) or "baseline"
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": variant,
            "status": "ok",
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful_ratio": rl["useful_flops_ratio"],
            "roofline_fraction": rl["roofline_fraction"],
            "note": NOTES.get((kind, rl["dominant"]), ""),
        })
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | variant | compute s | memory s | "
           "collective s | dominant | 6ND/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | | "
                         f"{r['status']}: {r['note']} | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('variant', 'baseline')} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


def run(verbose: bool = True, out_dir: str = "results/dryrun") -> dict:
    recs = load_records(out_dir)
    if not recs:
        if verbose:
            print("bench_roofline: no dry-run artifacts yet "
                  f"(run python -m repro.launch.dryrun --all); skipping")
        return {"rows": []}
    rows = table_rows(recs)
    md = to_markdown(rows)
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(md)
    if verbose:
        ok = [r for r in rows if r["status"] == "ok"]
        sk = [r for r in rows if r["status"] == "skipped"]
        er = [r for r in rows if r["status"] == "ERROR"]
        print(f"bench_roofline: {len(ok)} cells ok, {len(sk)} skipped, "
              f"{len(er)} errors -> results/roofline.md")
        for r in ok[:8]:
            print(f"  {r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                  f"dom={r['dominant']:10s} frac={r['roofline_fraction']:.3f}")
    return {"rows": rows}
