"""Benchmark harness: one module per paper table/claim + the roofline
aggregation.  ``python -m benchmarks.run [--fast] [--only name]``."""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="run a single bench (space|steps|reuse|throughput|"
                         "kernels|roofline)")
    args = ap.parse_args()

    from benchmarks import (bench_kernels, bench_reuse, bench_roofline,
                            bench_space, bench_steps, bench_throughput)
    benches = {
        "space": lambda: bench_space.run(),
        "steps": lambda: bench_steps.run(fast=args.fast),
        "reuse": lambda: bench_reuse.run(fast=args.fast),
        "throughput": lambda: bench_throughput.run(fast=args.fast),
        "kernels": lambda: bench_kernels.run(fast=args.fast),
        "roofline": lambda: bench_roofline.run(),
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    results = {}
    for name, fn in benches.items():
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = time.time()
        results[name] = fn()
        print(f"[{name}] {time.time() - t0:.1f}s")
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print("\nall benchmarks done -> results/benchmarks.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
