"""Benchmark harness: one module per paper table/claim + the roofline
aggregation.  ``python -m benchmarks.run [--fast] [--only name]``."""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes (CI)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: CI gate that every perf script stays "
                         "runnable on CPU (implies --fast)")
    ap.add_argument("--only", default=None,
                    choices=["space", "steps", "reuse", "throughput",
                             "kernels", "roofline"],
                    help="run a single bench")
    args = ap.parse_args()
    fast = args.fast or args.smoke

    from benchmarks import (bench_kernels, bench_reuse, bench_roofline,
                            bench_space, bench_steps, bench_throughput)
    benches = {
        "space": lambda: bench_space.run(),
        "steps": lambda: bench_steps.run(fast=fast),
        "reuse": lambda: bench_reuse.run(fast=fast),
        "throughput": lambda: bench_throughput.run(fast=fast),
        "kernels": lambda: bench_kernels.run(fast=fast),
        "roofline": lambda: bench_roofline.run(),
    }
    if args.only:
        benches = {args.only: benches[args.only]}

    import jax

    results = {}
    for name, fn in benches.items():
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = time.time()
        results[name] = fn()
        print(f"[{name}] {time.time() - t0:.1f}s")
        # each retained XLA:CPU executable holds mmap'd JIT code; a full
        # sweep accumulates enough to exhaust vm.max_map_count and segfault
        # the next section's compile — caches are per-section state anyway
        jax.clear_caches()
    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print("\nall benchmarks done -> results/benchmarks.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
