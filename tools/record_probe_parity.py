"""Record the golden linear-probe parity fixture.

Replays a deterministic mixed workload through the batched table and the
page-table allocator and records a sha256 digest of every intermediate
state and return vector.  The fixture pins the ``linear`` strategy to the
exact pre-ProbeStrategy-refactor behaviour: ``tests/test_probe_strategies.py
::test_linear_bitwise_parity`` replays the same workload through the
refactored code and compares digests bit-for-bit.

Regenerate (only when the linear algorithm itself is INTENTIONALLY changed):

    PYTHONPATH=src python -m tools.record_probe_parity
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

FIXTURE = os.path.join(os.path.dirname(__file__), os.pardir,
                       "tests", "fixtures", "probe_linear_parity.json")


def digest(*arrays) -> str:
    d = hashlib.sha256()
    for a in arrays:
        a = np.asarray(a)
        d.update(str(a.dtype).encode())
        d.update(str(a.shape).encode())
        d.update(a.tobytes())
    return d.hexdigest()


def state_digest(ht) -> str:
    return digest(ht.table, ht.num_keys, ht.num_tombs, ht.seed)


def replay(BT, PT, jnp):
    """Run the workload; returns the list of step records.

    Takes the modules as arguments so the parity test can inject the
    refactored implementations while this script records the originals.
    """
    records = []
    LPT = PT.for_strategy("linear")   # the strategy-bound facade

    # --- Leg 1: mixed-op churn on the batched table -----------------------
    rng = np.random.default_rng(0)
    ht = BT.create(64, seed=3)
    records.append({"leg": "create", "state": state_digest(ht)})
    for step in range(12):
        ops = jnp.asarray(rng.integers(0, 3, size=16), jnp.int32)
        keys = jnp.asarray(rng.integers(0, 4096, size=16), jnp.uint32)
        ht, ret = BT.apply_batch(ht, ops, keys)
        records.append({"leg": "apply", "step": step,
                        "state": state_digest(ht), "ret": digest(ret)})

    # no-reuse flavour (claim_tombstones=False) on the churned table
    keys = jnp.asarray(rng.integers(0, 4096, size=16), jnp.uint32)
    ht_nr, ret = BT.insert_batch(ht, keys, claim_tombstones=False)
    records.append({"leg": "insert_noreuse",
                    "state": state_digest(ht_nr), "ret": digest(ret)})

    # duplicate-heavy insert (leader/duplicate arbitration)
    dup = jnp.asarray(np.repeat(rng.integers(0, 4096, size=4), 4), jnp.uint32)
    ht, ret = BT.insert_batch(ht, dup)
    records.append({"leg": "insert_dup",
                    "state": state_digest(ht), "ret": digest(ret)})

    # Section 4.3 rebuild into a larger table
    ht_big = BT.rebuild(ht, 128)
    records.append({"leg": "rebuild", "state": state_digest(ht_big)})

    # --- Leg 2: the page-table allocator ----------------------------------
    table = LPT.create_table(32, seed=1)
    B, max_pages, page_size = 4, 8, 2
    seq_ids = jnp.arange(B, dtype=jnp.int32)
    positions = jnp.zeros((B,), jnp.int32)
    block = jnp.full((B, max_pages), -1, jnp.int32)
    for step in range(10):
        res, block = LPT.alloc_step_incremental(
            table, seq_ids, positions, block, page_size=page_size)
        table = res.table
        records.append({"leg": "alloc", "step": step,
                        "state": state_digest(table),
                        "ret": digest(res.write_slot, res.aborted, block)})
        positions = positions + 1

    # evict two lanes, then a plain (non-incremental) alloc_step
    evict = jnp.asarray([False, True, True, False])
    table = LPT.free_sequences(table, seq_ids, positions,
                              page_size=page_size, max_pages=max_pages,
                              active=evict)
    block = LPT.invalidate_block_rows(block, evict)
    records.append({"leg": "free", "state": state_digest(table),
                    "ret": digest(block)})
    res = LPT.alloc_step(table, seq_ids, positions, page_size=page_size)
    table = res.table
    records.append({"leg": "alloc_plain", "state": state_digest(table),
                    "ret": digest(res.write_slot, res.aborted)})

    # wait-free reads + rebuilt cache must pin too
    pages = LPT.lookup_pages(table, seq_ids, positions,
                            page_size=page_size, max_pages=max_pages)
    rebuilt = LPT.rebuild_block_table(table, seq_ids, max_pages)
    records.append({"leg": "lookup", "ret": digest(pages, rebuilt)})

    # Section 4.3 rehash (page permutation)
    fresh, old_slots, new_slots, live = LPT.rehash(table, 64)
    records.append({"leg": "rehash", "state": state_digest(fresh),
                    "ret": digest(old_slots, new_slots, live)})
    return records


def main():
    import jax.numpy as jnp

    from repro.core import batched as BT
    from repro.serving import page_table as PT

    records = replay(BT, PT, jnp)
    out = os.path.abspath(FIXTURE)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"note": "golden linear-probe digests; see module docstring",
                   "records": records}, f, indent=1)
    print(f"wrote {len(records)} records -> {out}")


if __name__ == "__main__":
    main()
