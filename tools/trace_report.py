#!/usr/bin/env python
"""Render and check JSONL traces from the obs span stream.

Input is one or more traces written by ``repro.obs.Tracer`` (the serve CLI's
``--trace``, or ``tests/_multihost.py --trace``).  Two modes:

* default — a human report per trace: the per-request timeline
  (arrival -> admit -> first token -> finish, with preemptions and
  re-homes), TTFT/TPOT histograms, and the table-health dashboard
  (per-shard tombstone-density and probe-p99 curves, migration progress).

* ``--check-invariants`` — machine mode for CI: replay the trace as a
  line-ordered state machine and fail (exit 1) on any violation of the
  trace invariants (also listed in ``src/repro/obs/README.md``):

  1. lifecycle containment — every ``decode`` / ``first_token`` /
     ``finish`` / ``preempt`` referencing a request falls inside one of
     that request's admitted intervals (``admit`` .. ``finish``/
     ``preempt``/``lose_host``), by line order;
  2. frozen-window writes — while a shard's lazy-resize window is open
     (``grow`` .. ``migrate_done``), a round that allocates pages on that
     shard (``decode`` with ``pages > 0``) must also report migration
     progress (a ``migrate`` event for that shard at the same clock) —
     inserts during the window go to the NEW table and the old one only
     drains, so allocation without migration service would mean the old
     table is being written;
  3. abort reconciliation — the summed ``lanes`` of all ``abort`` events
     equals the ``aborts`` field of the final ``summary`` event (no abort
     is latched device-side without surfacing in the span stream, and
     vice versa).

Within one clock value the emission order is line order (single-threaded
driver) and the checker relies on it — see ``obs/trace.py``.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Optional, Tuple

SPARK = " .:-=+*#%@"


def load(path: str) -> List[dict]:
    evs = []
    with open(path) as f:
        for n, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                evs.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{n}: bad JSON line: {e}")
    return evs


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

def check_invariants(path: str, evs: List[dict]) -> List[str]:
    """Replay the trace; return a list of violation strings (empty = OK)."""
    bad: List[str] = []
    admitted: Dict[int, bool] = {}         # req -> currently admitted
    open_window: Dict[int, bool] = {}      # shard -> grow window open
    migrate_at = {(e.get("shard"), e["clock"])
                  for e in evs if e["event"] == "migrate"}
    abort_lanes = 0
    summary: Optional[dict] = None

    def _admitted(req, n, what):
        if not admitted.get(req):
            bad.append(f"{path}:{n}: {what} for request {req} outside an "
                       f"admitted interval")

    for n, e in enumerate(evs, 1):
        ev = e["event"]
        if ev == "admit":
            admitted[e["req"]] = True
        elif ev == "first_token":
            _admitted(e["req"], n, "first_token")
        elif ev == "preempt":
            _admitted(e["req"], n, "preempt")
            admitted[e["req"]] = False
        elif ev == "finish":
            _admitted(e["req"], n, "finish")
            admitted[e["req"]] = False
        elif ev == "lose_host":
            for r in e.get("victims", []):
                admitted[r] = False       # lanes died with the host
            open_window.pop(e.get("shard"), None)
        elif ev == "decode":
            for r in e.get("reqs", []):
                _admitted(r, n, "decode")
            sid = e.get("shard")
            if (open_window.get(sid) and e.get("pages", 0) > 0
                    and (sid, e["clock"]) not in migrate_at):
                bad.append(
                    f"{path}:{n}: shard {sid} allocated {e['pages']} "
                    f"page(s) at clock {e['clock']} inside its frozen-old-"
                    f"table window with no migrate event that round")
        elif ev == "grow":
            if "shard" in e:
                open_window[e["shard"]] = True
        elif ev == "migrate_done":
            open_window[e.get("shard")] = False
        elif ev == "abort":
            abort_lanes += int(e.get("lanes", 0))
        elif ev == "summary":
            if summary is not None:
                bad.append(f"{path}:{n}: more than one summary event")
            summary = e

    if summary is None:
        bad.append(f"{path}: no summary event (truncated trace?)")
    else:
        if evs and evs[-1]["event"] != "summary":
            bad.append(f"{path}: summary is not the last event")
        want = summary.get("aborts")
        if want is not None and int(want) != abort_lanes:
            bad.append(f"{path}: abort events sum to {abort_lanes} lanes "
                       f"but summary reports aborts={int(want)}")
    return bad


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def _spark(xs: List[float], width: int = 48) -> str:
    if not xs:
        return ""
    if len(xs) > width:                   # downsample by max within bins
        step = len(xs) / width
        xs = [max(xs[int(i * step):max(int(i * step) + 1,
                                       int((i + 1) * step))])
              for i in range(width)]
    lo, hi = min(xs), max(xs)
    if hi <= lo:
        return SPARK[1] * len(xs)
    scale = (len(SPARK) - 1) / (hi - lo)
    return "".join(SPARK[int(round((x - lo) * scale))] for x in xs)


def _hist(xs: List[float], title: str, bins: int = 8,
          width: int = 40) -> List[str]:
    xs = [x for x in xs if x is not None and not math.isnan(x)]
    if not xs:
        return [f"  {title}: (no data)"]
    lo, hi = min(xs), max(xs)
    span = (hi - lo) or 1.0
    counts = [0] * bins
    for x in xs:
        counts[min(bins - 1, int((x - lo) / span * bins))] += 1
    peak = max(counts)
    out = [f"  {title}  n={len(xs)}  min={lo:.1f}  max={hi:.1f}"]
    for i, c in enumerate(counts):
        a = lo + span * i / bins
        b = lo + span * (i + 1) / bins
        bar = "#" * int(round(c / peak * width))
        out.append(f"    [{a:8.1f},{b:8.1f})  {bar} {c}")
    return out


def report(path: str, evs: List[dict]) -> None:
    print(f"== {path}  ({len(evs)} events) ==")

    # -- per-request timeline ---------------------------------------------
    reqs: Dict[int, dict] = {}
    for e in evs:
        if "req" not in e:
            continue
        r = reqs.setdefault(e["req"], {"admits": [], "preempts": 0})
        ev, c = e["event"], e["clock"]
        if ev == "arrival":
            r.setdefault("arrival", c)
        elif ev == "admit":
            r["admits"].append(c)
        elif ev == "first_token":
            r.setdefault("first_token", c)
        elif ev == "preempt":
            r["preempts"] += 1
        elif ev == "finish":
            r["finish"] = c
            r["ttft"] = e.get("ttft")
            r["tpot"] = e.get("tpot")
            r["tokens"] = e.get("tokens")
    rehomed = sum(1 for e in evs if e["event"] == "lose_host"
                  for _ in e.get("victims", []))
    print(f"-- requests: {len(reqs)} "
          f"(finished {sum(1 for r in reqs.values() if 'finish' in r)}, "
          f"re-homed {rehomed})")
    for rid in sorted(reqs):
        r = reqs[rid]
        admits = ",".join(str(a) for a in r["admits"]) or "-"
        print(f"  req {rid:4d}  arrive={r.get('arrival', '-'):>4} "
              f"admit={admits:>8}  first_tok={r.get('first_token', '-'):>4} "
              f"finish={r.get('finish', '-'):>4}  "
              f"preempts={r['preempts']}  tokens={r.get('tokens', '-')}")

    # -- latency histograms -----------------------------------------------
    fins = [r for r in reqs.values() if "finish" in r]
    for line in _hist([r.get("ttft") for r in fins], "TTFT (steps)"):
        print(line)
    for line in _hist([r.get("tpot") for r in fins], "TPOT (steps/token)"):
        print(line)

    # -- table health dashboard -------------------------------------------
    shards: Dict[int, dict] = {}
    for e in evs:
        if e["event"] == "shard_health":
            s = shards.setdefault(e["shard"], {"tomb": [], "p99": []})
            s["tomb"].append(float(e.get("tomb_density", 0.0)))
            s["p99"].append(float(e.get("probe_p99", 0.0)))
        elif e["event"] == "round":                 # batcher single-table
            h = e.get("health", {})
            s = shards.setdefault(0, {"tomb": [], "p99": []})
            s["tomb"].append(float(h.get("tomb_density", 0.0)))
            s["p99"].append(float(h.get("probe_p99", 0.0)))
    # migration progress comes from the migrate events themselves (one per
    # open-window round), not the health gauge — a window that drains in a
    # single round still gets its curve
    migs: Dict[int, List[float]] = {}
    for e in evs:
        if e["event"] == "migrate":
            sid = e.get("shard", 0)
            prev = migs.get(sid, [0.0])[-1] if sid in migs else 0.0
            migs.setdefault(sid, []).append(prev + float(e.get("moved", 0)))
    if shards:
        print("-- table health (per shard, one sample per round)")
        for sid in sorted(shards):
            s = shards[sid]
            print(f"  shard {sid}: tomb_density "
                  f"last={s['tomb'][-1]:.3f} |{_spark(s['tomb'])}|")
            print(f"  shard {sid}: probe_p99    "
                  f"last={s['p99'][-1]:.1f}   |{_spark(s['p99'])}|")
            if sid in migs:
                cum = migs[sid]
                print(f"  shard {sid}: migration    "
                      f"moved={cum[-1]:.0f} over {len(cum)} round(s) "
                      f"|{_spark(cum)}|")
    grows = [e for e in evs if e["event"] in ("grow", "rebuild")]
    for e in grows:
        if e["event"] == "grow":
            print(f"  grow @clock {e['clock']}: shard {e.get('shard', 0)} "
                  f"{e['n_pages_old']} -> {e['n_pages_new']} pages (lazy)")
        else:
            print(f"  rebuild @clock {e['clock']}: reason="
                  f"{e.get('reason')} (eager, no window)")
    for e in evs:
        if e["event"] == "lose_host":
            print(f"  lose_host @clock {e['clock']}: shard {e['shard']}, "
                  f"{len(e.get('victims', []))} victims re-homed")

    # -- counter plane roll-up --------------------------------------------
    tot: Dict[str, float] = {}
    for e in evs:
        if e["event"] == "round":
            for k, v in e.get("counters", {}).items():
                tot[k] = tot.get(k, 0) + v
    if tot:
        print("-- device counter plane (summed round deltas)")
        for k in sorted(tot):
            print(f"  {k:24s} {int(tot[k])}")

    summ = next((e for e in reversed(evs) if e["event"] == "summary"), None)
    if summ:
        keys = [k for k in ("completed", "submitted", "aborts", "rehomed",
                            "preemptive_evictions", "ttft_p99", "tpot_p99")
                if k in summ]
        print("-- summary: " + "  ".join(f"{k}={summ[k]}" for k in keys))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", help="JSONL trace file(s)")
    ap.add_argument("--check-invariants", action="store_true",
                    help="CI mode: exit 1 on any trace-invariant violation")
    args = ap.parse_args(argv)

    failures = 0
    for path in args.traces:
        evs = load(path)
        if args.check_invariants:
            bad = check_invariants(path, evs)
            if bad:
                failures += len(bad)
                for b in bad:
                    print(f"VIOLATION: {b}", file=sys.stderr)
            else:
                print(f"{path}: {len(evs)} events, invariants OK")
        else:
            report(path, evs)
    if args.check_invariants and failures:
        print(f"{failures} invariant violation(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
