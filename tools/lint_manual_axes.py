"""Build-breaking AST lint: every ``shard_map`` region must be FULLY manual
over all mesh axes, and every call site must route through the
``repro.dist.compat`` facade.

The pinned XLA rejects partially-auto shard_map regions that contain the
chunked attention loops (see ``src/repro/dist/README.md``), so the repo's
invariant is global: no region may carve out auto axes.  Concretely a
violation is any of:

  V1  a ``shard_map(...)`` call (direct or via ``functools.partial``)
      passing ``axis_names=`` — the facade's default is *all* mesh axes;
      naming a subset is exactly how a partially-auto region is made
  V2  ditto for the legacy spellings ``auto=`` / ``check_rep=`` — those
      bypass the facade's version shim
  V3  importing shard_map from jax (``jax.experimental.shard_map`` or the
      ``jax.shard_map`` attribute) anywhere outside ``dist/compat.py``

Usage:
  python -m tools.lint_manual_axes [paths...]     # default: src benchmarks
  python -m tools.lint_manual_axes --self-test    # prove a seeded
      violation turns the build red (CI runs this first)
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

FACADE = "dist/compat.py"
BANNED_KWARGS = ("axis_names", "auto", "check_rep")


def _is_shard_map_ref(node: ast.AST) -> bool:
    """``shard_map`` / ``X.shard_map`` — a reference to the mapped entry
    point, whether called directly or handed to functools.partial."""
    if isinstance(node, ast.Name):
        return node.id == "shard_map"
    if isinstance(node, ast.Attribute):
        return node.attr == "shard_map"
    return False


def lint_source(src: str, path: str) -> list[str]:
    """Violations in one file as ``path:line: message`` strings."""
    out = []
    in_facade = path.replace("\\", "/").endswith(FACADE)
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and not in_facade:
            if node.module and "shard_map" in node.module:
                out.append(f"{path}:{node.lineno}: V3 import from "
                           f"'{node.module}' — route shard_map through "
                           "repro.dist.compat")
        elif isinstance(node, ast.Import) and not in_facade:
            for alias in node.names:
                if "shard_map" in alias.name:
                    out.append(f"{path}:{node.lineno}: V3 import of "
                               f"'{alias.name}' — route shard_map through "
                               "repro.dist.compat")
        elif isinstance(node, ast.Attribute) and not in_facade:
            if (node.attr == "shard_map"
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "jax"):
                out.append(f"{path}:{node.lineno}: V3 jax.shard_map used "
                           "directly — route through repro.dist.compat")
        elif isinstance(node, ast.Call):
            # direct call, or partial(shard_map, ...) where the kwargs ride
            # on the partial call itself
            targets_sm = _is_shard_map_ref(node.func) or any(
                _is_shard_map_ref(a) for a in node.args)
            if not targets_sm or in_facade:
                continue  # the facade forwards axis_names/auto by design
            for kw in node.keywords:
                if kw.arg in BANNED_KWARGS:
                    which = ("V1" if kw.arg == "axis_names" else "V2")
                    out.append(
                        f"{path}:{node.lineno}: {which} shard_map called "
                        f"with {kw.arg}= — every region must be fully "
                        "manual over all mesh axes (omit it; the facade "
                        "defaults to all axes)")
    return out


def lint_paths(paths: list[str]) -> list[str]:
    out = []
    for root in paths:
        p = Path(root)
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            out.extend(lint_source(f.read_text(), str(f)))
    return out


_SEEDED_BAD = '''
from repro.dist.compat import shard_map
from jax.experimental.shard_map import shard_map as raw   # V3
import functools, jax

def f(fn, mesh, spec):
    a = shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                  axis_names=("model",))                  # V1
    b = functools.partial(shard_map, mesh=mesh, in_specs=(spec,),
                          out_specs=spec, auto={"data"})  # V2
    c = jax.shard_map(fn, mesh=mesh, in_specs=(spec,),    # V3
                      out_specs=spec)
    return a, b, c
'''

_SEEDED_GOOD = '''
from repro.dist.compat import shard_map
import functools

def f(fn, mesh, spec):
    a = shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                  check_vma=False)
    b = functools.partial(shard_map, mesh=mesh, in_specs=(spec,),
                          out_specs=spec, check_vma=False)
    return a, b
'''


def self_test() -> int:
    """The lint must flag every seeded violation class and stay quiet on
    the clean twin — proof the CI step can actually turn red."""
    bad = lint_source(_SEEDED_BAD, "seeded_bad.py")
    kinds = {line.split(": ")[1].split(" ")[0] for line in bad}
    ok = kinds == {"V1", "V2", "V3"} and not lint_source(
        _SEEDED_GOOD, "seeded_good.py")
    print(f"self-test: {len(bad)} seeded violations flagged "
          f"({', '.join(sorted(kinds)) or 'none'}); clean twin "
          f"{'quiet' if ok else 'NOT quiet / classes missing'}")
    for line in bad:
        print("  ", line)
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks"])
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()
    violations = lint_paths(args.paths or ["src", "benchmarks"])
    if violations:
        print(f"lint_manual_axes: {len(violations)} violations")
        for line in violations:
            print("  ", line)
        return 1
    print("lint_manual_axes: all shard_map regions fully manual "
          f"({', '.join(args.paths or ['src', 'benchmarks'])})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
