"""Device-sharded distributed hash table (DHT): keys hash-routed to owner
shards with the MoE-dispatch all_to_all pattern, applied locally with the
batched lock-free-analog engine.

Spawns itself with 8 fake CPU devices (the dry-run rule: only launch/dryrun
gets 512).  Run: PYTHONPATH=src python examples/distributed_dht.py
"""
import os
import subprocess
import sys

BODY = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import sharded as SHT
from repro.core.spec import OP_DELETE, OP_INSERT, OP_LOOKUP

mesh = jax.make_mesh((8,), ("model",))
st, apply_fn = SHT.make_sharded_table(mesh, "model", m_global=4096,
                                      capacity=128)
rng = np.random.default_rng(0)
B = 512
keys = jnp.asarray(rng.choice(1 << 20, size=B, replace=False), jnp.uint32)

st, ret, ovf = apply_fn(st, jnp.full((B,), OP_INSERT, jnp.int32), keys)
print(f"   inserted {int((ret == 1).sum())}/{B} "
      f"(overflowed routes: {int(ovf.sum())})")

st, ret, _ = apply_fn(st, jnp.full((B,), OP_LOOKUP, jnp.int32), keys)
print(f"   lookups found {int(ret.sum())}/{B}")

half = jnp.asarray(np.arange(B) % 2 == 0)
st, ret, _ = apply_fn(st, jnp.where(half, OP_DELETE, OP_LOOKUP), keys)
st, ret, _ = apply_fn(st, jnp.full((B,), OP_LOOKUP, jnp.int32), keys)
print(f"   after deleting half: lookups find {int(ret.sum())} "
      f"(expect {B // 2})")
assert int(ret.sum()) == B // 2
shards = np.asarray(st.num_keys)
print(f"   per-shard live keys: {shards.tolist()} (hash-balanced)")
print("[example] distributed_dht OK")
"""

if __name__ == "__main__":
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env.setdefault("PYTHONPATH", "src")
    print("[example] 8-shard DHT over a device mesh (subprocess)")
    out = subprocess.run([sys.executable, "-c", BODY], env=env,
                         capture_output=True, text=True, timeout=600)
    print(out.stdout, end="")
    if out.returncode != 0:
        print(out.stderr)
        raise SystemExit(1)
