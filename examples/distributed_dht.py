"""Host-sharded distributed page table: hash-prefix routing, per-shard
admission, lazy incremental resize and elastic host loss — the
``serving/sharded_table`` + ``sched/router`` layer driven end-to-end
through the simulated multi-host harness (``tests/_multihost``).

Spawns itself with 8 fake CPU devices (the dry-run rule: only launch/dryrun
gets 512) and pins each shard's tables to its own fake device.
Run: PYTHONPATH=src python examples/distributed_dht.py
"""
import os
import subprocess
import sys

BODY = """
import sys
import numpy as np
import jax

sys.path.insert(0, "tests")
import _multihost as MH

from repro.dist import table_shard as TS
from repro.dist.fault_tolerance import elastic_table_plan
from repro.serving.sched import synthetic_workload

# --- 1. the routing layer: hash-prefix manifest --------------------------
HOSTS = 4
man = TS.ShardManifest.balanced(HOSTS)
owners = man.owner_of_seq(np.arange(1, 257, dtype=np.uint32))
counts = np.bincount(owners, minlength=HOSTS)
print(f"   manifest: {1 << man.prefix_bits} prefixes over {HOSTS} hosts; "
      f"256 seqs land as {counts.tolist()} (hash-balanced)")

# --- 2. the storm: admission + lazy grow + host loss under traffic -------
cluster = MH.SimCluster(hosts=HOSTS, pages_per_shard=32, slots_per_shard=3,
                        page_size=4, max_len=32, megastep_k=4,
                        fail_on_abort=True, place_on_devices=True,
                        verbose=True)
wl = synthetic_workload(32, vocab_size=256, max_len=32, seed=0,
                        prompt_len=(2, 5), max_new=(20, 28))
print(f"   storm: {len(wl)} requests over {HOSTS} hosts on "
      f"{len(jax.devices())} fake devices (grow @r3, host loss @r6)")
s = cluster.run_storm(wl, grow_round=3, lose_round=6)
print(f"   drained in {int(s['rounds'])} rounds: "
      f"completed={int(s['completed'])}/{int(s['submitted'])} "
      f"rehomed={int(s['rehomed'])} grows={int(s['pool_grows'])} "
      f"aborts={int(s['aborts_observed'])}")
assert int(s["completed"]) == int(s["submitted"]), "lost requests"
assert int(s["aborts_observed"]) == 0

# --- 3. the elastic plan the loss triggered ------------------------------
new_man, shape, names = elastic_table_plan(man, lost_shard=HOSTS - 1,
                                           model_parallel=16)
print(f"   elastic_table_plan: survivors={new_man.live_shards()} "
      f"mesh={dict(zip(names, shape))}")
assert len(new_man.live_shards()) == len(cluster.spt.live_shards())
print("[example] distributed_dht OK")
"""

if __name__ == "__main__":
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.join(root, "src")
    print("[example] 4-host sharded page table on fake devices (subprocess)")
    out = subprocess.run([sys.executable, "-c", BODY], env=env, cwd=root,
                         capture_output=True, text=True, timeout=600)
    print(out.stdout, end="")
    if out.returncode != 0:
        print(out.stderr)
        raise SystemExit(1)
