"""Quickstart: the paper's hash table in three layers.

1. The faithful layer — Algorithms 1-6 executed event-by-event under an
   adversarial scheduler, with a linearizability check.
2. The TPU-native batched layer — scatter-min arbitration, tombstone reuse.
3. The integration — the table as a paged-KV page allocator.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import batched as BT
from repro.core import schedulers as SCH
from repro.core import simulator as SIM
from repro.core.linearizability import check_history
from repro.core.spec import OP_DELETE, OP_INSERT, OP_LOOKUP

print("=" * 64)
print("1) faithful layer: concurrent processes, adversarial interleaving")
rng = np.random.default_rng(0)
P, K, m = 6, 4, 32
wl = SCH.random_workload(rng, P=P, K=K, num_keys=8)   # high contention
sched = SCH.uniform_schedule(rng, P, T=4000)
state = SIM.simulate(wl, m, sched, mode=SIM.MODE_LLSC, check_inv=True)
rows = SIM.history_arrays(state, wl)
ok = check_history(rows)
print(f"   {len(rows)} ops, {P} processes, random schedule "
      f"-> linearizable: {ok}, invariants held: {bool(state.inv_ok)}")
assert ok

print("=" * 64)
print("2) batched TPU layer: one mixed batch, tombstone reuse")
ht = BT.create(64)
keys = jnp.arange(20, dtype=jnp.uint32)
ht, ret = BT.insert_batch(ht, keys)
print(f"   inserted {int(ret.sum())} keys; occupancy "
      f"{float(BT.occupancy(ht)):.2f}")
ht, _ = BT.delete_batch(ht, keys[:10])
print(f"   deleted 10 -> tombstones {int(ht.num_tombs)}")
ht, ret = BT.insert_batch(ht, keys[:10] + 1000)
print(f"   re-inserted 10 new keys; occupancy still "
      f"{float(BT.occupancy(ht)):.2f} (tombstones reclaimed: "
      f"{10 - int(ht.num_tombs)})")

print("=" * 64)
print("3) the integration: table slots ARE physical KV pages")
from repro.serving import page_table as PT
pt = PT.for_strategy("linear")
table = pt.create_table(32)
seqs = jnp.arange(4, dtype=jnp.int32)
for pos in range(12):
    table, slots, _ = pt.alloc_step(table, seqs,
                                    jnp.full((4,), pos, jnp.int32),
                                    page_size=4)
print(f"   4 sequences x 12 tokens @ page_size 4 -> "
      f"{int(table.num_keys)} pages allocated")
table = pt.free_sequences(table, seqs[:2], jnp.full((2,), 12, jnp.int32),
                          page_size=4, max_pages=8)
print(f"   evicted 2 sequences -> {int(table.num_tombs)} tombstoned pages "
      f"(immediately reusable, no compaction)")
print("quickstart OK")
