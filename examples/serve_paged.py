"""Serve a small model with batched requests through the paged engine:
continuous batching under the SLO-aware scheduler, chunked-prefill
admission, sequence eviction, tombstone-reuse page recycling, proactive
headroom control, and a correctness check of decode-vs-forward on one
request stream.

Run: PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import ContinuousBatcher
from repro.models.registry import get_model
from repro.serving import engine as EG
from repro.serving.sched import Scheduler, synthetic_workload

cfg = get_smoke_config("qwen2.5-32b")
model = get_model(cfg)
params, _ = model.init(cfg, jax.random.PRNGKey(0))

print("[example] greedy-decode correctness vs full forward")
B, T = 2, 16
toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
ref, _ = model.forward(cfg, params, toks)
state, _ = EG.make_decode_state(cfg, B, S_max=64, page_size=8)
step = jax.jit(EG.make_serve_step(cfg, S_max=64, page_size=8))
for t in range(T):
    logits, state = step(params, state, toks[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
err = float(jnp.max(jnp.abs(logits - ref[:, -1].astype(jnp.float32))))
print(f"   last-token logits err vs forward: {err:.2e}")
assert err < 6e-2

print("[example] continuous batching under churn (tombstone reuse), "
      "megastep K=4: one dispatch per 4 greedy tokens")
srv = ContinuousBatcher(cfg, params, batch=4, max_len=48, page_size=8,
                        megastep_k=4)
for r in range(6):
    srv.decode_round(8)
    st = srv.table_stats()
    print(f"   round {r}: evictions={srv.evictions:3d} "
          f"live={int(st.live_pages):3d} tombs={int(st.tombstones):3d} "
          f"occupancy={float(st.occupancy):.3f}")
assert srv.sched.stats.aborts == 0, "proactive batcher should never abort"
print("[example] serve_paged OK — pages recycled in place, no rebuild")

print("[example] SLO-aware scheduling on an OVERCOMMITTED pool (the "
      "forecaster keeps the allocator out of ABORT)")
sched = Scheduler(slots=4, page_size=8, max_len=48, megastep_k=4,
                  policy="deadline", proactive=True)
wl = synthetic_workload(12, vocab_size=cfg.vocab_size, max_len=48, seed=0,
                        slo_fraction=0.5, arrival_every=2)
srv2 = ContinuousBatcher(cfg, params, batch=4, max_len=48, page_size=8,
                         megastep_k=4, scheduler=sched,
                         n_pages=14,           # < half the worst-case plan
                         auto_refill=False, verify_block_table=True)
sched.submit_many(wl)
assert srv2.run_until_drained(max_rounds=400), "workload did not drain"
s = sched.stats
print(f"   completed={s.completed} aborts={s.aborts} "
      f"aborts_avoided={s.aborts_avoided} grows={s.pool_grows} "
      f"preempted={s.preemptive_evictions} "
      f"deadline_misses={s.deadline_misses}")
lat = sched.latency_summary()
print(f"   queue_wait p50/p99 = {lat['queue_wait_p50']:.0f}/"
      f"{lat['queue_wait_p99']:.0f} steps, "
      f"ttft p50/p99 = {lat['ttft_p50']:.0f}/{lat['ttft_p99']:.0f} steps")
assert s.completed == 12 and s.aborts == 0
print("[example] scheduler OK — zero ABORTs on an overcommitted pool")
