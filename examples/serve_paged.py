"""Serve a small model with batched requests through the paged engine:
continuous batching, sequence eviction, tombstone-reuse page recycling, and
a correctness check of decode-vs-forward on one request stream.

Run: PYTHONPATH=src python examples/serve_paged.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import ContinuousBatcher
from repro.models.registry import get_model
from repro.serving import engine as EG

cfg = get_smoke_config("qwen2.5-32b")
model = get_model(cfg)
params, _ = model.init(cfg, jax.random.PRNGKey(0))

print("[example] greedy-decode correctness vs full forward")
B, T = 2, 16
toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
ref, _ = model.forward(cfg, params, toks)
state, _ = EG.make_decode_state(cfg, B, S_max=64, page_size=8)
step = jax.jit(EG.make_serve_step(cfg, S_max=64, page_size=8))
for t in range(T):
    logits, state = step(params, state, toks[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
err = float(jnp.max(jnp.abs(logits - ref[:, -1].astype(jnp.float32))))
print(f"   last-token logits err vs forward: {err:.2e}")
assert err < 6e-2

print("[example] continuous batching under churn (tombstone reuse), "
      "megastep K=4: one dispatch per 4 greedy tokens")
srv = ContinuousBatcher(cfg, params, batch=4, max_len=48, page_size=8,
                        megastep_k=4)
for r in range(6):
    srv.decode_round(8)
    st = srv.table_stats()
    print(f"   round {r}: evictions={srv.evictions:3d} "
          f"live={int(st.live_pages):3d} tombs={int(st.tombstones):3d} "
          f"occupancy={float(st.occupancy):.3f}")
final = srv.table_stats()
assert float(final.occupancy) < 1.0, "allocator should never fill up"
print("[example] serve_paged OK — pages recycled in place, no rebuild")
