"""End-to-end training driver: train a ~100M-parameter qwen2.5-family model
for a few hundred steps on synthetic data with the full production loop
(AdamW + cosine schedule, remat, checkpointing, watchdog, dedup data
pipeline) and verify the loss decreases.

CPU-sized by default (~15M params, 300 steps); pass --full for the ~100M
variant if you have the patience.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.train import TrainRunner


def small_lm(full: bool) -> ModelConfig:
    if full:  # ~100M
        return ModelConfig(
            name="lm-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
            head_dim=64, qkv_bias=True, tie_embeddings=True, rope_theta=1e4)
    return ModelConfig(  # ~15M — minutes on CPU
        name="lm-15m", family="dense", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=2, d_ff=1024, vocab_size=8192,
        head_dim=64, qkv_bias=True, tie_embeddings=True, rope_theta=1e4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_lm(args.full)
    from repro.models.registry import get_model
    import jax
    params, _ = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[example] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    runner = TrainRunner(cfg, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                         dedup=True)
    t0 = time.time()
    _, losses = runner.run(batch=args.batch, seq_len=args.seq,
                           steps=args.steps, log_every=25)
    dt = time.time() - t0
    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    toks = args.steps * args.batch * args.seq
    print(f"[example] {dt:.0f}s ({toks/dt:.0f} tok/s CPU); "
          f"loss {first:.3f} -> {last:.3f}")
    assert last < first - 0.1, "loss did not decrease"
    print("[example] train_lm OK")


if __name__ == "__main__":
    main()
